"""`repro.obs` acceptance suite (the PR 8 tentpole):

* tracer — span nesting/timing invariants, exception capture, the
  disabled-tracer zero-allocation fast path, dual-clock recording, and
  the deterministic virtual fingerprint (two traced chaos replays at the
  same seed hash identically);
* metrics — registry snapshot/delta arithmetic (gauges keep their
  "after" level), nearest-rank percentiles, fixed-bucket histograms,
  ``PackStats.delta``;
* JAX cost attribution — pinned compile-vs-execute split for one engine
  bucket and the jit-cache-growth detection semantics;
* exporters — Perfetto ``trace_event`` schema validity (round-trip
  through :func:`repro.obs.summarize_trace`), malformed-file rejection,
  and the ``telemetry`` block shape;
* logging — ``repro.*`` namespacing and idempotent setup.
"""

import json
import logging
import tracemalloc

import numpy as np
import pytest

from repro import obs
from repro.obs import (
    FITNESS,
    METRICS,
    TRACER,
    FitnessAccounting,
    Histogram,
    MetricsRegistry,
    Tracer,
    nearest_rank,
    summarize_trace,
    telemetry,
    trace_events,
    virtual_fingerprint,
    write_metrics,
    write_trace,
)


@pytest.fixture(autouse=True)
def _pristine_tracer():
    """Every test starts and ends with the global tracer disabled."""
    TRACER.disable()
    yield
    TRACER.disable()


# ---------------------------------------------------------------------------
# tracer: spans, nesting, exceptions
# ---------------------------------------------------------------------------

def test_span_nesting_and_timing_invariants():
    tr = Tracer()
    tr.enable()
    with tr.span("outer", cat="t"):
        with tr.span("inner", cat="t", args={"k": 1}):
            pass
        with tr.span("inner2", cat="t"):
            pass
    outer, inner, inner2 = tr.spans
    assert [s.id for s in tr.spans] == [0, 1, 2]  # deterministic sequence
    assert outer.parent is None
    assert inner.parent == outer.id and inner2.parent == outer.id
    assert inner.args == {"k": 1}
    # children start no earlier than the parent and fit inside it
    assert inner.wall_t0 >= outer.wall_t0
    assert inner.wall_dur + inner2.wall_dur <= outer.wall_dur
    assert tr._stack == []  # balanced enter/exit


def test_enable_resets_ids_and_buffer():
    tr = Tracer()
    tr.enable()
    with tr.span("a"):
        pass
    tr.enable()
    with tr.span("b"):
        pass
    assert [s.name for s in tr.spans] == ["b"]
    assert tr.spans[0].id == 0


def test_span_records_exception_and_reraises():
    tr = Tracer()
    tr.enable()
    with pytest.raises(ValueError, match="boom"):
        with tr.span("failing"):
            raise ValueError("boom")
    assert tr.spans[0].args["error"] == "ValueError: boom"
    assert tr._stack == []  # exception path still pops the stack


def test_virtual_clock_recorded_and_restored():
    tr = Tracer()
    tr.enable()
    now = [10.0]
    prev = tr.set_virtual_clock(lambda: now[0])
    assert prev is None
    with tr.span("event"):
        now[0] = 12.5
    assert tr.set_virtual_clock(prev) is not None  # restore returns ours
    s = tr.spans[0]
    assert s.vt0 == 10.0 and s.vdur == 2.5
    tr.enable()
    with tr.span("no-clock"):
        pass
    assert tr.spans[0].vt0 is None  # outside a service run: wall view only


def test_timed_measures_wall_even_when_disabled():
    tr = Tracer()  # disabled
    with tr.timed("cell") as sp:
        sum(range(1000))
    assert sp.wall_us > 0.0
    assert tr.spans == []  # no span recorded while disabled
    tr.enable()
    with tr.timed("cell") as sp:
        pass
    assert sp.wall_us >= 0.0 and tr.spans[0].name == "cell"


def test_disabled_span_is_shared_noop_and_allocation_free():
    assert TRACER.span("a", cat="x") is TRACER.span("b")
    n0 = len(TRACER.spans)
    for _ in range(10):  # warm up any lazy caches before measuring
        with TRACER.span("hot"):
            pass
    import repro.obs.tracer as tracer_mod

    only_tracer = [tracemalloc.Filter(True, tracer_mod.__file__)]
    tracemalloc.start()
    snap1 = tracemalloc.take_snapshot().filter_traces(only_tracer)
    for _ in range(1000):
        with TRACER.span("hot"):
            pass
    snap2 = tracemalloc.take_snapshot().filter_traces(only_tracer)
    tracemalloc.stop()
    assert len(TRACER.spans) == n0
    # the disabled path performs no per-span allocation (shared _NOOP
    # singleton): anything tracemalloc attributes to the tracer module must
    # be O(1) interpreter incidentals (a cold frame object), never O(n) —
    # an allocating implementation would show >=1000 objects here
    grew = [s for s in snap2.compare_to(snap1, "lineno") if s.size_diff > 0]
    assert sum(s.count_diff for s in grew) < 50
    assert sum(s.size_diff for s in grew) < 4096


def test_traced_decorator_noop_when_disabled():
    calls = []

    @obs.traced("deco.fn", cat="t")
    def fn(x):
        calls.append(x)
        return x + 1

    assert fn(1) == 2  # disabled: passthrough
    tr_spans_before = len(TRACER.spans)
    TRACER.enable()
    assert fn(2) == 3
    assert TRACER.spans[-1].name == "deco.fn"
    assert calls == [1, 2]
    assert tr_spans_before == 0


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_snapshot_delta_arithmetic():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.gauge("g").set(5)
    reg.histogram("h", bounds=(1.0, 10.0)).observe(0.5)
    before = reg.snapshot()
    reg.counter("c").inc(4)
    reg.gauge("g").set(7)
    reg.histogram("h").observe(3.0)
    d = MetricsRegistry.delta(before, reg.snapshot())
    assert d["counters"]["c"] == 4
    assert d["gauges"]["g"] == 7  # a gauge is a level, not a flow
    assert d["histograms"]["h"]["count"] == 1
    assert d["histograms"]["h"]["counts"] == [0, 1, 0]
    # None before → after passes through unchanged
    assert MetricsRegistry.delta(None, reg.snapshot())["counters"]["c"] == 5


def test_metrics_collectors_polled_at_snapshot_and_fault_isolated():
    reg = MetricsRegistry()
    state = {"n": 1}
    reg.register_collector("ok", lambda: dict(state))
    reg.register_collector("broken", lambda: 1 / 0)
    snap1 = reg.snapshot()
    state["n"] = 3
    snap2 = reg.snapshot()
    assert snap1["ok"]["n"] == 1 and snap2["ok"]["n"] == 3
    assert snap2["broken"]["error"].startswith("ZeroDivisionError")
    assert MetricsRegistry.delta(snap1, snap2)["ok"]["n"] == 2
    reg.reset()  # instruments cleared, collectors kept
    assert reg.snapshot()["counters"] == {} and "ok" in reg.snapshot()


def test_nearest_rank_is_always_an_observed_value():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert nearest_rank(xs, 50) == 2.0
    assert nearest_rank(xs, 100) == 4.0
    assert nearest_rank(xs, 1) == 1.0
    assert nearest_rank(range(1, 101), 95) == 95
    assert nearest_rank([7.5], 99) == 7.5
    with pytest.raises(ValueError):
        nearest_rank([], 50)
    with pytest.raises(ValueError):
        nearest_rank(xs, 0)
    # matches the numpy inverted-cdf method on a random sample
    rng = np.random.default_rng(0)
    sample = rng.normal(size=257)
    for q in (50, 90, 95, 99):
        assert nearest_rank(sample, q) == pytest.approx(
            float(np.percentile(sample, q, method="inverted_cdf")))


def test_histogram_buckets_and_percentiles():
    h = Histogram(bounds=(0.001, 0.01, 0.1, 1.0))
    for v in (0.0005, 0.005, 0.005, 0.05, 5.0):
        h.observe(v)
    assert h.counts == [1, 2, 1, 0, 1]
    assert h.count == 5 and h.min == 0.0005 and h.max == 5.0
    assert h.percentile(50) == 0.01  # bucket upper bound
    assert h.percentile(99) == 5.0  # overflow bucket reports the max
    j = h.to_json()
    assert j["count"] == 5 and j["counts"] == h.counts
    with pytest.raises(ValueError, match="sorted"):
        Histogram(bounds=(1.0, 0.5))


def test_pack_stats_delta():
    from repro.engine.packed import PackStats

    s = PackStats(hits=10, misses=4, evictions=1)
    d = s.delta((7, 4, 0))
    assert (d.hits, d.misses, d.evictions) == (3, 0, 1)
    assert d.hit_rate == 1.0


# ---------------------------------------------------------------------------
# JAX cost attribution
# ---------------------------------------------------------------------------

def test_fitness_accounting_cache_growth_detection():
    acct = FitnessAccounting()
    cache = {"size": 0}

    def call(grow: bool) -> None:
        with acct.measure("fake", (4, 2, 8, 3), "fixed",
                          cache_size=lambda: cache["size"]):
            if grow:
                cache["size"] += 1

    call(grow=True)   # compile: cache grew during the call
    call(grow=False)  # execute (jit-cache hit)
    call(grow=False)
    table = acct.to_json()
    rec = table["fake|4x2x8x3|fixed"]
    assert rec["calls"] == 3 and rec["compiles"] == 1
    assert rec["execute_calls"] == 2  # calls - compiles == jit-cache hits
    assert rec["compile_us"] > 0.0 and rec["execute_us"] >= 0.0
    assert rec["execute_us_mean"] == pytest.approx(rec["execute_us"] / 2)
    acct.reset()
    assert acct.to_json() == {}


def test_engine_bucket_compile_vs_execute_split_pinned():
    """One engine bucket, N fitness calls: exactly one compile, N-1 cache
    hits — the pallas path attributes first-call autotune+build as compile."""
    from repro.core import ObjectiveWeights, Workload, build_problem, synthetic_system
    from repro.core.workload_model import random_layered_workflow
    from repro.engine import ENGINES, pack

    problem = build_problem(
        synthetic_system(3, seed=5),
        Workload((random_layered_workflow(9, seed=5, max_cores=4),)),
    )
    packed = pack(problem)
    fitness = ENGINES.get("pallas").population_fitness(packed, ObjectiveWeights())
    A = np.random.default_rng(0).integers(0, problem.num_nodes,
                                          (4, problem.num_tasks))
    FITNESS.reset()
    n = 3
    for _ in range(n):
        fitness(A)
    key = f"pallas|{'x'.join(str(d) for d in packed.bucket)}|fixed"
    rec = FITNESS.to_json()[key]
    assert rec["calls"] == n
    assert rec["compiles"] == 1  # first call per key builds the kernel
    assert rec["execute_calls"] == n - 1
    FITNESS.reset()


def test_engine_dispatch_counters_tick():
    before = METRICS.snapshot()
    from repro.core import ObjectiveWeights, Workload, build_problem, synthetic_system
    from repro.core.workload_model import random_layered_workflow
    from repro.engine import ENGINES, pack

    problem = build_problem(
        synthetic_system(3, seed=6),
        Workload((random_layered_workflow(8, seed=6, max_cores=4),)),
    )
    fitness = ENGINES.get("pallas").population_fitness(
        pack(problem), ObjectiveWeights())
    fitness(np.zeros((2, problem.num_tasks), dtype=np.int32))
    d = MetricsRegistry.delta(before, METRICS.snapshot())["counters"]
    # the pallas engine routed through exactly one makespan dispatch path
    assert d.get("engine.dispatch.pallas", 0) + d.get("engine.dispatch.ref", 0) >= 1


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_perfetto_export_schema_and_summary(tmp_path):
    TRACER.enable()
    vclock = TRACER.set_virtual_clock(lambda: 42.0)
    try:
        with TRACER.span("outer", cat="test"):
            with TRACER.span("inner", cat="test", args={"k": "v"}):
                pass
    finally:
        TRACER.set_virtual_clock(vclock)
    p = write_trace(tmp_path / "t.json")
    obj = json.loads(p.read_text())
    assert obj["displayTimeUnit"] == "ms"
    evs = obj["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["ph"] for e in evs} <= {"M", "X"}
    assert all(isinstance(e["ts"], (int, float)) and e["dur"] >= 0 for e in xs)
    # both spans appear on the wall view (pid 1) and the virtual view (pid 2)
    assert sorted(e["pid"] for e in xs) == [1, 1, 2, 2]
    inner = next(e for e in xs if e["name"] == "inner" and e["pid"] == 1)
    assert inner["args"]["k"] == "v" and inner["args"]["parent"] == 0
    s = summarize_trace(p)
    assert s["wall_spans"] == 2 and s["virtual_spans"] == 2
    assert s["categories"]["test"]["count"] == 2
    assert {t["name"] for t in s["top_spans_us"]} == {"outer", "inner"}


def test_summarize_trace_rejects_malformed_files(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"ph": "X", "ts": "zero"}]}))
    with pytest.raises(ValueError, match="ts/dur"):
        summarize_trace(bad)
    bad.write_text(json.dumps({"events": []}))
    with pytest.raises(ValueError, match="traceEvents"):
        summarize_trace(bad)


def test_telemetry_block_shape(tmp_path):
    before = METRICS.snapshot()
    METRICS.counter("t.obs.test").inc(2)
    block = telemetry(before)
    assert block["metrics"]["counters"]["t.obs.test"] == 2
    assert isinstance(block["engine_fitness"], dict)
    assert block["spans"] == 0  # tracer disabled
    p = write_metrics(tmp_path / "m.json", block)
    flat = json.loads(p.read_text())
    assert flat["metrics.counters.t.obs.test"] == 2


# ---------------------------------------------------------------------------
# determinism: traced chaos replay
# ---------------------------------------------------------------------------

def test_traced_chaos_replay_fingerprint_bit_identical():
    """Two traced service runs of the same chaos trace at the same seed
    produce byte-identical virtual fingerprints (ids, nesting, names,
    virtual timestamps, args — everything but wall time)."""
    from repro.service import SchedulingService, ServiceConfig, generate_trace

    trace = generate_trace(
        12, seed=3, rate=2.0, families=("stgs", "random", "tpu"),
        chaos={"horizon": 300.0, "failure_rate": 0.03, "outage_mean": 20.0},
    )
    cfg = ServiceConfig(batch_window=0.5, seed=3, max_retries=2,
                        backoff_base=0.5, backoff_cap=8.0)

    def traced_run():
        TRACER.enable()  # resets ids/origin → replayable sequence
        try:
            SchedulingService(trace.system, cfg).run(trace)
            return virtual_fingerprint(TRACER.spans), len(TRACER.spans)
        finally:
            TRACER.disable()

    fp_a, n_a = traced_run()
    fp_b, n_b = traced_run()
    assert n_a == n_b and n_a > 0
    assert fp_a == fp_b
    # and the trace covered the acceptance span families
    names = {s.name for s in TRACER.spans}
    assert "service.run" in names
    assert "service.dispatch" in names
    assert any(n.startswith("event.") for n in names)
    assert "solve.route" in names or "solve.with_fallback" in names


# ---------------------------------------------------------------------------
# logging
# ---------------------------------------------------------------------------

def test_logging_namespaced_and_idempotent():
    log = obs.logger("service")
    assert log.name == "repro.service"
    root = logging.getLogger("repro")
    assert any(isinstance(h, logging.NullHandler) for h in root.handlers)
    n0 = len(root.handlers)
    obs.setup_logging()
    obs.setup_logging()  # second call must not stack handlers
    assert len(root.handlers) == n0 + 1
    stream = [h for h in root.handlers if not isinstance(h, logging.NullHandler)]
    root.removeHandler(stream[0])  # leave global state as found
