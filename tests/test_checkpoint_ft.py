"""Checkpointing (atomicity, retention, roundtrip incl. bf16), trainer
resume-equivalence, straggler detection, remesh planning."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.data.pipeline import DataConfig
from repro.distributed.fault_tolerance import (
    StragglerDetector,
    plan_remesh,
    replacement_schedule,
)
from repro.models.registry import get_model
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def test_save_restore_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((5,), jnp.bfloat16), "d": jnp.asarray(3, jnp.int32)},
    }
    save_pytree(tree, tmp_path / "ck")
    out = restore_pytree(tree, tmp_path / "ck")
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_save_is_atomic(tmp_path):
    tree = {"a": jnp.zeros((4,))}
    save_pytree(tree, tmp_path / "ck")
    # a second save replaces wholesale; no .tmp residue
    save_pytree({"a": jnp.ones((4,))}, tmp_path / "ck")
    assert not (tmp_path / "ck.tmp").exists()
    out = restore_pytree(tree, tmp_path / "ck")
    np.testing.assert_array_equal(np.asarray(out["a"]), np.ones(4))


def test_leaf_count_mismatch_rejected(tmp_path):
    save_pytree({"a": jnp.zeros((4,))}, tmp_path / "ck")
    with pytest.raises(ValueError, match="leaves"):
        restore_pytree({"a": jnp.zeros(4), "b": jnp.zeros(2)}, tmp_path / "ck")


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (10, 20, 30):
        mgr.save(s, {"x": jnp.asarray(s)})
    assert mgr.latest_step() == 30
    assert mgr.all_steps() == [20, 30]  # step 10 garbage-collected
    out, step = mgr.restore({"x": jnp.asarray(0)})
    assert step == 30 and int(out["x"]) == 30


def test_trainer_resume_equivalence(tmp_path):
    """Interrupted-and-resumed training must reproduce the uninterrupted
    loss trajectory exactly (deterministic data + state restore)."""
    api = get_model("qwen2.5-3b")
    cfg = dataclasses.replace(api.reduced, dtype="float32", vocab=64)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20, schedule="constant")
    data_cfg = DataConfig(vocab=64, seq_len=32, global_batch=4, seed=5)

    def make(dirname, steps):
        return Trainer(
            api, cfg, opt_cfg, data_cfg,
            TrainerConfig(steps=steps, checkpoint_every=5, checkpoint_dir=str(tmp_path / dirname),
                          microbatches=1, remat=False, resume=True),
        )

    # uninterrupted 10 steps
    full = make("full", 10).run()
    # interrupted at 5, then resumed to 10
    make("resume", 5).run()
    resumed = make("resume", 10).run()
    assert resumed.resumed_from == 5
    np.testing.assert_allclose(resumed.losses, full.losses[5:], rtol=1e-5)


def test_straggler_detector_flags_injected_delay():
    det = StragglerDetector(patience=2)
    flagged = []
    for step in range(40):
        dt = 1.0 + (0.01 * (step % 3))
        if step in (25, 26, 27, 28):
            dt = 5.0  # injected straggler
        if det.observe(step, dt):
            flagged.append(step)
    assert flagged, "straggler not detected"
    assert all(24 <= s <= 29 for s in flagged)


def test_straggler_detector_ignores_noise():
    det = StragglerDetector()
    rng = np.random.default_rng(0)
    assert not any(det.observe(s, 1.0 + 0.05 * rng.standard_normal()) for s in range(50))


def test_plan_remesh_shapes():
    p2 = plan_remesh(surviving_pods=2)
    assert p2.mesh_shape == (2, 16, 16)
    p1 = plan_remesh(surviving_pods=1)
    assert p1.mesh_shape == (16, 16)
    assert p1.axis_names == ("data", "model")
    with pytest.raises(ValueError):
        plan_remesh(surviving_pods=0)


def test_replacement_schedule_places_jobs():
    jobs = [{"name": f"job{i}", "flops": 1e15 * (i + 1), "bytes_in": 1.0} for i in range(4)]
    rep = replacement_schedule(jobs, surviving_pods=2)
    assert rep.schedule.violations == 0
    assert np.isfinite(rep.schedule.makespan)
