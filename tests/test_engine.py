"""`repro.engine` acceptance suite (the PR 4 tentpole):

* cross-backend equivalence — a randomized (hypothesis-guarded) sweep
  asserting the oracle-f32, jax, and pallas-interpret backends return
  bit-identical makespans/violations on the same ``PackedProblem``;
* the one simulator — ``engine.sim`` reproduces HEFT's schedules and the
  service's truth-execution finish times exactly (executor replay with no
  perturbation == oracle timing, bit for bit);
* pack cache — fingerprint-keyed LRU: content-identical rebuilds reuse the
  padded arrays and device buffers; the service surfaces the hit rate;
* registry — capability metadata, plugin registration, alias resolution,
  and Scenario-level engine selection.
"""

import numpy as np
import pytest

from repro.core import (
    ObjectiveWeights,
    Scenario,
    Workload,
    build_problem,
    mri_system,
    mri_workload,
    run_scenario,
    scenario_from_json,
    synthetic_system,
)
from repro.core.evaluator import evaluate_assignment
from repro.core.heuristics import heft, olb
from repro.core.simulator import execute
from repro.core.workload_model import random_layered_workflow
from repro.engine import (
    ENGINES,
    EngineCapabilities,
    EngineRegistry,
    PackedProblem,
    ScheduleEngine,
    bucket_of,
    pack,
    pack_cache,
)
from repro.engine.sim import CoreSim, ready_times_all, run_schedule

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # container without hypothesis: keep the suite runnable
    HAVE_HYPOTHESIS = False


def _random_problem(seed: int, tasks: int, nodes: int, max_cores: int = 8):
    system = synthetic_system(nodes, seed=seed)
    wf = random_layered_workflow(tasks, seed=seed, max_cores=max_cores, comm=True)
    return build_problem(system, Workload((wf,)))


# -----------------------------------------------------------------------------
# cross-backend bit-for-bit equivalence
# -----------------------------------------------------------------------------


def _assert_backends_agree(problem, seed: int, pop: int = 6):
    rng = np.random.default_rng(seed)
    A = rng.integers(0, problem.num_nodes, (pop, problem.num_tasks))
    packed = pack(problem)
    results = {}
    for name in ("oracle", "jax", "pallas"):
        eng = ENGINES.get(name)
        assert eng.capabilities.exact_f32
        # jax/pallas consume the canonical PackedProblem directly; the
        # oracle walks the raw problem — same model, same bits
        target = problem if name == "oracle" else packed
        _, mk = eng.population_fitness(target, ObjectiveWeights())(A)
        results[name] = np.asarray(mk).astype(np.float32)
    np.testing.assert_array_equal(results["oracle"], results["jax"])
    np.testing.assert_array_equal(results["oracle"], results["pallas"])
    # violations agree with the oracle count
    for k in range(pop):
        s32 = evaluate_assignment(problem, A[k], dtype=np.float32)
        assert np.float32(s32.makespan) == results["oracle"][k]


@pytest.mark.parametrize("seed,tasks,nodes", [(0, 7, 3), (1, 13, 4), (2, 21, 5)])
def test_cross_backend_bit_for_bit_fixed(seed, tasks, nodes):
    _assert_backends_agree(_random_problem(seed, tasks, nodes), seed)


def test_cross_backend_bit_for_bit_mri():
    _assert_backends_agree(build_problem(mri_system(), mri_workload()), 123)


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        tasks=st.integers(3, 18),
        nodes=st.integers(2, 5),
        max_cores=st.sampled_from([2, 4, 8]),
    )
    def test_cross_backend_bit_for_bit_randomized(seed, tasks, nodes, max_cores):
        problem = _random_problem(seed, tasks, nodes, max_cores)
        _assert_backends_agree(problem, seed, pop=4)


# -----------------------------------------------------------------------------
# one simulator: heuristics and truth execution share engine.sim
# -----------------------------------------------------------------------------


def test_sim_reproduces_oracle_timing_bit_for_bit():
    problem = _random_problem(5, 15, 4)
    rng = np.random.default_rng(5)
    A = rng.integers(0, problem.num_nodes, problem.num_tasks)
    start, finish, violations = run_schedule(problem, A)
    sched = evaluate_assignment(problem, A)
    np.testing.assert_array_equal(start, sched.start)
    np.testing.assert_array_equal(finish, sched.finish)
    assert violations == sched.violations


def test_truth_execution_matches_oracle_exactly():
    """The service's truth executor replays through engine.sim — with no
    perturbation its finish times are the oracle's, bit for bit."""
    problem = build_problem(mri_system(), mri_workload())
    sched = heft(problem)
    report = execute(problem, sched)
    finishes = np.array([log.finish for log in report.logs])
    np.testing.assert_array_equal(finishes, sched.finish)
    assert report.makespan == sched.makespan
    assert report.slowdown == 1.0


def test_heft_greedy_state_equals_oracle_rescore():
    """HEFT's incremental CoreSim bookkeeping must agree with the oracle's
    re-evaluation of its own assignment (identical semantics, one sim)."""
    for seed, tasks, nodes in [(3, 12, 3), (7, 25, 5)]:
        problem = _random_problem(seed, tasks, nodes)
        for solver in (heft, olb):
            sched = solver(problem)
            re = evaluate_assignment(problem, sched.assignment)
            assert sched.makespan == re.makespan
            assert sched.violations == re.violations


def test_coresim_kth_and_commit_track_a_naive_model():
    problem = _random_problem(11, 6, 3)
    sim = CoreSim(problem, exact=True)
    naive = [np.zeros(max(int(c), 1)) for c in sim.caps]
    rng = np.random.default_rng(11)
    t = 0.0
    for _ in range(50):
        i = int(rng.integers(0, problem.num_nodes))
        c = int(rng.integers(1, max(int(sim.caps[i]), 1) + 1))
        t += float(rng.random())
        idx = np.argsort(naive[i], kind="stable")[:c]
        expect = naive[i][idx[-1]]
        assert sim.kth_free(i, c) == expect
        naive[i][idx] = t
        sim.commit(i, c, t)


def test_ready_times_all_matches_scalar_path():
    problem = _random_problem(13, 14, 4)
    rng = np.random.default_rng(13)
    A = rng.integers(0, problem.num_nodes, problem.num_tasks)
    _, finish, _ = run_schedule(problem, A)
    indptr, indices = problem.pred_csr
    for j in range(problem.num_tasks):
        ready = ready_times_all(problem, j, A, finish)
        assert ready.shape == (problem.num_nodes,)
        # the f32 factor path agrees with the exact division path closely
        ps = indices[indptr[j] : indptr[j + 1]]
        for i in range(problem.num_nodes):
            exact = problem.release[j]
            for p in ps:
                rate = problem.dtr[int(A[p]), i]
                tt = 0.0 if int(A[p]) == i else float(problem.data[p]) / rate
                exact = max(exact, float(finish[p]) + tt)
            assert ready[i] == pytest.approx(exact, rel=1e-5, abs=1e-4)


# -----------------------------------------------------------------------------
# pack cache
# -----------------------------------------------------------------------------


def test_pack_cache_hits_on_content_identical_rebuild():
    system = synthetic_system(3, seed=31)
    wf = random_layered_workflow(9, seed=31, max_cores=4)
    p1 = build_problem(system, Workload((wf,)))
    p2 = build_problem(system, Workload((wf,)))  # fresh arrays, same content
    stats = pack_cache().stats
    h0, m0, _ = stats.snapshot()
    packed1 = pack(p1)
    packed2 = pack(p2)
    h1, m1, _ = stats.snapshot()
    assert packed2 is packed1  # one PackedProblem serves both builds
    assert h1 - h0 >= 1
    assert m1 - m0 <= 1
    # device buffers are cached on the shared instance: one transfer total
    assert packed1.device_arrays()["durations"] is packed2.device_arrays()["durations"]


def test_pack_is_read_only_and_padding_is_neutral():
    problem = _random_problem(17, 10, 3)
    packed = pack(problem)
    assert isinstance(packed, PackedProblem)
    assert packed.bucket == bucket_of(problem)
    with pytest.raises(ValueError):
        packed.durations[0, 0] = 1.0  # read-only canonical arrays
    # real region round-trips exactly
    T, N = problem.num_tasks, problem.num_nodes
    np.testing.assert_array_equal(
        packed.durations[:T, :N], problem.durations.astype(np.float32)
    )
    assert packed.feasible[T:, 0].all()
    assert not packed.feasible[:T, N:].any()


def test_pack_rejects_too_small_bucket():
    problem = _random_problem(19, 12, 3)
    with pytest.raises(ValueError, match="exceeds bucket"):
        pack(problem, (4, 4, 4, 1))


def test_pack_cache_is_byte_bounded():
    from repro.engine.packed import PackCache

    problems = [_random_problem(40 + s, 8, 3) for s in range(4)]
    sizes = [pack(p, use_cache=False).nbytes for p in problems]
    cache = PackCache(capacity=64, max_bytes=int(sum(sizes[:2]) + sizes[2] // 2))
    for i, p in enumerate(problems[:3]):
        cache.get_or_build(("k", i), lambda p=p: pack(p, use_cache=False))
    assert cache.retained_bytes <= cache.max_bytes  # evicted down to budget
    assert len(cache) < 3
    # an entry larger than the whole budget is served but never retained
    tiny = PackCache(capacity=64, max_bytes=16)
    built = tiny.get_or_build(("big",), lambda: pack(problems[0], use_cache=False))
    assert built.nbytes > tiny.max_bytes
    assert len(tiny) == 0 and tiny.retained_bytes == 0


def test_service_surfaces_pack_cache_hit_rate():
    from repro.service import ServiceConfig, generate_trace, serve_trace

    trace = generate_trace(24, seed=3, rate=6.0, families=("mri",))
    result = serve_trace(trace, config=ServiceConfig(batch_window=0.5, seed=3))
    assert set(result.pack_cache) >= {"hits", "misses", "hit_rate"}
    assert result.summary()["pack_cache"] == result.pack_cache


def test_pack_reused_across_solve_cache_misses():
    """The satellite scenario: resubmitting the same workflow with different
    solve parameters misses the *solve* cache (new key) but must hit the
    *pack* LRU (same problem fingerprint) — no re-pad, no re-transfer."""
    from repro.core.workload_model import mri_w1
    from repro.service import ServiceConfig, SchedulingService, Trace
    from repro.service.traces import Submission

    opts = {"pop_size": 8, "generations": 3}
    subs = tuple(
        Submission(
            id=f"s{k}", tenant="t", time=0.1 * k, family="mri", workflow=mri_w1(),
            technique="ga", solver_options={**opts, "seed": k},  # distinct solve keys
        )
        for k in range(3)
    )
    trace = Trace(name="pack-reuse", system=mri_system(), submissions=subs, events=())
    pack_cache().clear()  # absolute hit/miss assertions below need an empty LRU
    # batch_window=0 admits each submission alone: three separate GA solves
    service = SchedulingService(trace.system, ServiceConfig(batch_window=0.0))
    result = service.run(trace)
    assert all(r.status == "completed" for r in result.records)
    assert not any(r.cache_hit for r in result.records)  # solve keys differ
    assert result.solver_calls == 3
    # ... but the problem content is identical: one pack, two reuses.
    # (The monitor converges to factor 1.0 with no perturbation, so the
    # rebuilt problems stay fingerprint-identical across admissions.)
    assert result.pack_cache["misses"] == 1
    assert result.pack_cache["hits"] == 2
    assert result.pack_cache["hit_rate"] > 0.6


def test_generated_stgs_trace_warms_pack_cache():
    """Pin the trace-generator behavior that makes the pack LRU observable:
    stgs submissions vary their GA seed per tenant, so content-identical
    resubmissions miss the *solve* cache (distinct option keys) yet reuse
    the fingerprint-keyed *pack*.  Before this, every repeat carried the
    same options, was absorbed by the solve cache before reaching a solver,
    and the service lane reported pack hit_rate == 0.0 forever."""
    from repro.service import ServiceConfig, generate_trace, serve_trace

    pack_cache().clear()
    # stgs only: three distinct workflows across 24 submissions, so repeated
    # content is certain; seeds drawn from {0..3} guarantee repeated
    # (workflow, options) pairs never all collapse into the solve cache
    trace = generate_trace(24, seed=5, rate=6.0, families=("stgs",))
    result = serve_trace(trace, config=ServiceConfig(batch_window=0.5, seed=5))
    assert all(r.status == "completed" for r in result.records)
    assert result.pack_cache["hits"] > 0
    assert 0.0 < result.pack_cache["hit_rate"] <= 1.0


# -----------------------------------------------------------------------------
# registry + scenario-level engine selection
# -----------------------------------------------------------------------------


def test_registry_metadata_and_aliases():
    assert set(ENGINES.names()) >= {"oracle", "jax", "pallas"}
    assert ENGINES.get("jnp") is ENGINES.get("jax")  # legacy alias
    assert ENGINES.get("numpy") is ENGINES.get("oracle")
    assert ENGINES.get("auto").name in ("jax", "pallas")
    assert ENGINES.capabilities("jax").supports_batch
    assert not ENGINES.capabilities("oracle").supports_batch
    with pytest.raises(KeyError, match="unknown engine"):
        ENGINES.get("warp-drive")


def test_plugin_engine_registers_and_routes():
    reg = EngineRegistry()

    from repro.engine import register_engine

    @register_engine("twice-oracle", registry=reg)
    class TwiceOracle(ScheduleEngine):
        capabilities = EngineCapabilities(supports_population=True)

        def population_fitness(self, problem, weights=None, *, core_cap=None):
            base = ENGINES.get("oracle").population_fitness(problem, weights)

            def fitness(assignments):
                obj, mk = base(assignments)
                return obj * 2.0, mk

            return fitness

    problem = _random_problem(23, 6, 3)
    A = np.random.default_rng(23).integers(0, problem.num_nodes, (3, problem.num_tasks))
    obj2, mk2 = reg.get("twice-oracle").population_fitness(problem)(A)
    obj1, mk1 = ENGINES.get("oracle").population_fitness(problem)(A)
    np.testing.assert_array_equal(np.asarray(mk2), np.asarray(mk1))
    np.testing.assert_allclose(np.asarray(obj2), 2.0 * np.asarray(obj1))
    with pytest.raises(ValueError, match="already registered"):
        reg.register("twice-oracle", TwiceOracle)


def test_scenario_engine_field_round_trips_and_routes():
    import json

    sc = Scenario(
        name="engine-routing",
        system=mri_system(),
        workload=mri_workload(),
        technique="ga",
        engine="pallas",
        solver_options={"pop_size": 8, "generations": 4},
        orchestration=__import__("repro.core.api", fromlist=["OrchestrationConfig"]).OrchestrationConfig(max_rounds=1),
    )
    obj = sc.to_json()
    assert obj["scenario"]["engine"] == "pallas"
    rt = scenario_from_json(json.loads(json.dumps(obj)))
    assert rt.engine == "pallas"
    assert rt.to_json() == obj  # bit-exact round trip with the new field
    result = run_scenario(sc)
    assert result.final_schedule.technique == "ga"
    assert result.final_schedule.violations == 0


def test_engine_selection_never_leaks_into_exact_solvers():
    """A scenario pinning engine=pallas with auto routing must still be able
    to fall back to MILP/HEFT (they never see a backend kwarg)."""
    from repro.core.api import route_problem

    problem = build_problem(mri_system(), mri_workload())
    rep = route_problem(problem, technique="auto", engine="pallas")
    assert rep.schedule.violations == 0
