"""HLO cost-model tests: trip-count scaling, dot flops, collective parsing —
the §Roofline measurement infrastructure must itself be correct."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_costs import analyze_hlo_text
from repro.launch.dryrun import collective_bytes


def test_scan_trip_count_scaling():
    """cost_analysis counts a while body once; the parser must scale by the
    trip count."""
    def step(xs, x):
        def body(c, w):
            return jnp.tanh(c @ w), ()
        c, _ = jax.lax.scan(body, x, xs)
        return c.sum()

    trips, m, k, n = 9, 8, 16, 16
    comp = jax.jit(step).lower(
        jax.ShapeDtypeStruct((trips, k, n), jnp.float32),
        jax.ShapeDtypeStruct((m, k), jnp.float32),
    ).compile()
    costs = analyze_hlo_text(comp.as_text())
    dot_flops = 2 * m * k * n
    assert costs.flops >= trips * dot_flops
    assert costs.flops < trips * dot_flops * 1.5  # no gross overcount
    # raw cost_analysis undercounts by ~trips
    raw = comp.cost_analysis()["flops"]
    assert costs.flops > raw * (trips - 2)


def test_single_dot_flops_exact():
    m, k, n = 32, 64, 16
    comp = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
    ).compile()
    costs = analyze_hlo_text(comp.as_text())
    assert costs.flops == pytest.approx(2 * m * k * n, rel=0.05)


def test_batched_dot_flops():
    b, m, k, n = 4, 8, 32, 16
    comp = jax.jit(lambda a, c: jnp.einsum("bmk,bkn->bmn", a, c)).lower(
        jax.ShapeDtypeStruct((b, m, k), jnp.float32),
        jax.ShapeDtypeStruct((b, k, n), jnp.float32),
    ).compile()
    costs = analyze_hlo_text(comp.as_text())
    assert costs.flops == pytest.approx(2 * b * m * k * n, rel=0.05)


def test_nested_scan_multiplies():
    def step(xs):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ c2), ()
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, ()
        c, _ = jax.lax.scan(outer, xs, None, length=5)
        return c.sum()

    comp = jax.jit(step).lower(jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile()
    costs = analyze_hlo_text(comp.as_text())
    dot = 2 * 16 * 16 * 16
    assert costs.flops >= 15 * dot  # 5 × 3 nested trips
    assert costs.flops < 15 * dot * 1.6


def test_collective_bytes_regex():
    hlo = """
ENTRY %main {
  %x = f32[16,128]{1,0} parameter(0)
  %all-reduce.1 = f32[16,128]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[4,256]{1,0} all-gather(%x), dimensions={1}
  %cp = (f32[8]{0}, f32[8]{0}) collective-permute(%x, %x), source_target_pairs={{0,1}}
}
"""
    out = collective_bytes(hlo)
    assert out["bytes"]["all-reduce"] == 16 * 128 * 4
    assert out["bytes"]["all-gather"] == 4 * 256 * 2
    assert out["bytes"]["collective-permute"] == 2 * 8 * 4
    assert out["counts"]["all-reduce"] == 1


def test_parser_consistent_with_cost_analysis_loop_free():
    """On a loop-free program the parser must agree with XLA's own
    cost_analysis (which is correct there) to within elementwise noise."""
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def loss(w, x):
        h = jnp.tanh(x @ w)
        h = jnp.tanh(h @ w)
        return jnp.sum(h ** 2)

    comp = jax.jit(jax.grad(loss)).lower(w, x).compile()
    parsed = analyze_hlo_text(comp.as_text()).flops
    raw = comp.cost_analysis()["flops"]
    assert parsed == pytest.approx(raw, rel=0.1), (parsed, raw)
