"""Sampling strategies, evaluation loop, differentiable pipeline
parallelism (training through ppermute)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig
from repro.models.registry import get_model
from repro.serve.sampling import SamplingConfig, sample
from repro.train.evaluate import evaluate
from tests.test_distributed import run_with_devices


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def test_greedy_is_argmax():
    logits = jnp.asarray([[1.0, 3.0, 2.0], [0.5, 0.1, 0.9]])
    out = sample(logits, jax.random.PRNGKey(0), SamplingConfig(temperature=0.0))
    assert out.tolist() == [1, 2]


def test_topk_restricts_support():
    logits = jnp.asarray([[10.0, 9.0, -50.0, -50.0]])
    cfg = SamplingConfig(temperature=1.0, top_k=2)
    draws = {int(sample(logits, jax.random.PRNGKey(s), cfg)[0]) for s in range(50)}
    assert draws <= {0, 1}
    assert len(draws) == 2  # both plausible tokens appear


def test_topp_keeps_head_of_distribution():
    logits = jnp.asarray([[5.0, 4.0, -10.0, -10.0, -10.0]])
    cfg = SamplingConfig(temperature=1.0, top_p=0.9)
    draws = {int(sample(logits, jax.random.PRNGKey(s), cfg)[0]) for s in range(50)}
    assert draws <= {0, 1}


def test_temperature_zero_vs_high_entropy():
    logits = jnp.zeros((1, 16))
    cfg = SamplingConfig(temperature=1.0)
    draws = {int(sample(logits, jax.random.PRNGKey(s), cfg)[0]) for s in range(60)}
    assert len(draws) > 5  # uniform logits → spread


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

def test_evaluate_perplexity_bounded_by_vocab():
    api = get_model("qwen2.5-3b")
    cfg = dataclasses.replace(api.reduced, dtype="float32", vocab=64)
    params = api.init(jax.random.PRNGKey(0), cfg)
    out = evaluate(api, cfg, params,
                   DataConfig(vocab=64, seq_len=32, global_batch=4, seed=99),
                   batches=2)
    assert 0 < out["nll"] < np.log(64) + 1.0  # untrained ≈ uniform
    assert out["tokens"] == 2 * 4 * 31


def test_evaluate_improves_after_training():
    from repro.optim import adamw
    from repro.train.train_step import make_train_step
    from repro.data.pipeline import SyntheticLMStream

    api = get_model("qwen2.5-3b")
    cfg = dataclasses.replace(api.reduced, dtype="float32", vocab=64)
    params = api.init(jax.random.PRNGKey(0), cfg)
    # held-out eval: SAME seed (same mixture), far step offset (unseen data)
    eval_cfg = DataConfig(vocab=64, seq_len=32, global_batch=4, seed=0,
                          mixture_components=2)
    before = evaluate(api, cfg, params, eval_cfg, batches=2)
    opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=40)
    opt = adamw.init(opt_cfg, params)
    step = jax.jit(make_train_step(api, cfg, opt_cfg, remat=False))
    train = SyntheticLMStream(DataConfig(vocab=64, seq_len=32, global_batch=8,
                                         seed=0, mixture_components=2))
    for _ in range(40):
        batch = {k: jnp.asarray(v) for k, v in train.next_batch().items()}
        params, opt, _ = step(params, opt, batch)
    after = evaluate(api, cfg, params, eval_cfg, batches=2)
    assert after["nll"] < before["nll"] - 0.3  # same mixture family transfers


# ---------------------------------------------------------------------------
# differentiable pipeline parallelism
# ---------------------------------------------------------------------------

def test_pipeline_gradients_match_sequential():
    run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_mesh
    from repro.distributed.pipeline import pipeline_forward, split_stages

    L, d, M, mb, S = 4, 8, 2, 2, 4
    w = jax.random.normal(jax.random.PRNGKey(0), (L, d, d)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, S, d))

    def block_fn(stage_w, h):
        def one(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(one, h, stage_w)
        return h

    def seq_loss(w):
        out = jax.vmap(lambda xm: block_fn(w, xm))(x)
        return jnp.sum(out ** 2)

    mesh = make_mesh((2,), ("stage",))

    def pp_loss(w):
        stages = split_stages(w, 2)
        out = pipeline_forward(block_fn, stages, x, mesh)
        return jnp.sum(out ** 2)

    g_seq = jax.grad(seq_loss)(w)
    g_pp = jax.grad(pp_loss)(w)
    np.testing.assert_allclose(np.asarray(g_pp), np.asarray(g_seq),
                               atol=1e-5, rtol=1e-4)
    print("pipeline gradients == sequential OK")
    """, n=2)
