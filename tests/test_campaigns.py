"""Campaign API: grid expansion determinism, filter semantics, fingerprint
dedupe (solve-cache counters), shape-bucket batch grouping (pack-cache
counters), typed columnar ResultSet round-trips, the Table IX deviation
report, the service runner, and the ``python -m repro campaign`` CLI."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.campaigns import (
    Axis,
    Campaign,
    ResultSet,
    SkipRule,
    builtin_campaign,
    campaign_from_json,
    cell_scenario,
    matches,
    run_campaign,
)
from repro.campaigns.results import Column

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _mini_campaign(techniques=("heft",), sizes=(3, 4), family="mri"):
    """Cheap grid: the mri family ignores 'size', so every size cell is
    content-identical — the dedupe hot path."""
    return Campaign(
        name="mini",
        axes=(
            Axis("family", (family,)),
            Axis("size", tuple(sizes)),
            Axis("technique", tuple(techniques)),
        ),
        defaults={"system": "mri", "engine": "auto"},
    )


# ---------------------------------------------------------------------------
# expansion
# ---------------------------------------------------------------------------


def test_expansion_deterministic_order_and_indices():
    c = builtin_campaign("smoke")
    a = c.expand()
    b = c.expand()
    assert [cell.coords for cell in a] == [cell.coords for cell in b]
    assert [cell.index for cell in a] == list(range(len(a)))
    # first axis outermost, values in listed order
    assert [cell.coords["size"] for cell in a] == [5, 5, 5, 50, 50, 50]
    assert [cell.coords["technique"] for cell in a] == ["milp", "ga", "heft"] * 2


def test_zipped_axis_key_collision_rejected():
    """A zipped axis's value keys clobbering another axis would yield a
    silently wrong grid — reject at construction."""
    with pytest.raises(ValueError, match="set by both axis"):
        Campaign(
            name="clash",
            axes=(
                Axis("technique", ("heft", "olb")),
                Axis("scale", ({"size": 5, "technique": "milp"},), zipped=True),
            ),
        )


def test_policy_distinguishes_dedupe_identity():
    """Two cells identical except for their routing policy must NOT dedupe
    onto one solve — the policy changes what the solver does."""
    c = Campaign(
        name="pol",
        axes=(
            Axis(
                "policy",
                ({"rules": [], "final": "heft"}, {"rules": [], "final": "olb"}),
            ),
        ),
        defaults={"family": "mri", "system": "mri", "technique": "policy"},
    )
    rs = run_campaign(c)
    rows = rs.rows()
    assert rs.meta["stats"]["solver_calls"] == 2
    assert rs.meta["stats"]["dedup_hits"] == 0
    assert [r["technique_used"] for r in rows] == ["heft", "olb"]


def test_zipped_axis_merges_correlated_coords():
    c = Campaign(
        name="z",
        axes=(
            Axis("scale", ({"size": 5, "nodes": 2}, {"size": 9, "nodes": 3}),
                 zipped=True),
        ),
    )
    cells = c.expand()
    assert [(x.coords["size"], x.coords["nodes"]) for x in cells] == [(5, 2), (9, 3)]
    with pytest.raises(ValueError, match="zipped axis"):
        Axis("bad", (1, 2), zipped=True)


def test_filter_semantics_include_exclude_skip():
    base = dict(
        axes=(
            Axis("size", (5, 10, 50)),
            Axis("technique", ("milp", "heft")),
        ),
    )
    # matcher conditions: scalar equality, list membership, numeric range
    assert matches({"size": 5}, {"size": 5})
    assert matches({"size": [5, 10]}, {"size": 10})
    assert matches({"size": {"min": 6, "max": 50}}, {"size": 10})
    assert not matches({"size": {"min": 6}}, {"size": 5})
    assert not matches({"missing": 1}, {"size": 5})

    c = Campaign(name="f", include=({"technique": "milp"},), **base)
    assert {x.coords["technique"] for x in c.expand()} == {"milp"}

    c = Campaign(name="f", exclude=({"size": {"min": 11}},), **base)
    assert {x.coords["size"] for x in c.expand()} == {5, 10}

    c = Campaign(
        name="f",
        skip=(SkipRule(where={"technique": "milp", "size": {"min": 26}},
                       reason="size"),),
        **base,
    )
    cells = c.expand()
    skipped = [x for x in cells if x.skipped]
    assert [(x.coords["size"], x.coords["technique"]) for x in skipped] == [
        (50, "milp")
    ]
    assert skipped[0].skipped == "size"
    # skip keeps the cell in the grid: indices stay contiguous over all cells
    assert [x.index for x in cells] == list(range(6))


def test_campaign_json_round_trip_and_unknown_keys():
    c = builtin_campaign("table9")
    rt = campaign_from_json(json.dumps(c.to_json()))
    assert rt == c
    bad = c.to_json()
    bad["campaign"]["axess"] = []
    with pytest.raises(ValueError, match="did you mean 'axes'"):
        campaign_from_json(bad)
    bad2 = c.to_json()
    bad2["campaign"]["axes"][0]["valuess"] = []
    with pytest.raises(ValueError, match="did you mean 'values'"):
        campaign_from_json(bad2)


def test_cell_scenario_compiles_and_unknown_family_suggests():
    c = _mini_campaign()
    cells = c.expand()
    sc = cell_scenario(c, cells[0])
    assert sc.technique == "heft"
    assert sc.workload.num_tasks == 7  # W1 (3) + W2 (4)
    bad = Campaign(name="b", axes=(Axis("family", ("lyered",)),),
                   defaults={"size": 5})
    with pytest.raises(ValueError, match="did you mean 'layered'"):
        cell_scenario(bad, bad.expand()[0])


# ---------------------------------------------------------------------------
# inline runner: dedupe + batching counters
# ---------------------------------------------------------------------------


def test_identical_cells_solved_once_with_cache_counters():
    # mri ignores the size axis → 3 size values × heft = 3 content-identical
    # cells; the solve cache must prove a single solver call
    rs = run_campaign(_mini_campaign(sizes=(3, 4, 5)))
    stats = rs.meta["stats"]
    assert stats["cells"] == 3
    assert stats["solver_calls"] == 1
    assert stats["dedup_hits"] == 2
    assert stats["cache"]["hits"] == 2
    rows = rs.rows()
    assert [r["dedup"] for r in rows] == [False, True, True]
    assert rows[1]["dedup_of"] == rows[0]["cell"]
    assert len({r["fingerprint"] for r in rows}) == 1
    assert len({r["makespan"] for r in rows}) == 1
    assert [r["wall_us"] == 0.0 for r in rows] == [False, True, True]


def test_same_bucket_ga_cells_batch_and_packs_are_reused():
    # two distinct layered instances in the same pow2 shape bucket with the
    # same (weights, options, engine) must run as ONE ga_sweep program
    def campaign():
        return Campaign(
            name="batch",
            axes=(Axis("size", (6, 7)),),
            defaults={
                "family": "layered",
                "nodes": 3,
                "seed": 0,
                "technique": "ga",
                "engine": "auto",
                "solver_options": {
                    "ga": {"seed": 0, "pop_size": 8, "generations": 3}
                },
            },
        )

    rs = run_campaign(campaign())
    stats = rs.meta["stats"]
    assert stats["batched_groups"] == 1
    assert stats["batched_submissions"] == 2
    assert stats["solver_calls"] == 2
    assert all(r["batched"] and r["group_size"] == 2 for r in rs)
    assert all(r["violations"] == 0 for r in rs)
    # identical re-run in-process: the engine pack LRU serves the packs
    # built above (fingerprint-keyed), proving cross-run pack reuse
    rs2 = run_campaign(campaign())
    assert rs2.meta["stats"]["pack_cache"]["hits"] >= 2
    assert [r["makespan"] for r in rs2] == [r["makespan"] for r in rs]


def test_dedup_of_violated_schedule_shares_it_and_counts_a_miss():
    """Duplicates of a representative whose schedule is invalid must still
    carry that schedule (violations visible), and must count as solve-cache
    misses, not hits — mirroring the admission batcher's twin accounting."""
    import numpy as np

    from repro.core.api import ObjectiveWeights, SolveReport, SolverRegistry
    from repro.core.evaluator import Schedule

    reg = SolverRegistry()

    def bad(problem, weights=ObjectiveWeights(), **kw):
        t = problem.num_tasks
        sched = Schedule(
            assignment=np.zeros(t, dtype=np.int64),
            start=np.zeros(t), finish=np.ones(t),
            makespan=1.0, usage=1.0, objective=1.0,
            violations=3, technique="bad",
        )
        return SolveReport(schedule=sched, problem=problem)

    reg.register("bad", bad)
    c = Campaign(
        name="dup-bad",
        axes=(Axis("size", (3, 4)),),  # mri ignores size → identical cells
        defaults={"family": "mri", "system": "mri", "technique": "bad"},
    )
    rs = run_campaign(c, registry=reg)
    rows = rs.rows()
    assert rows[1]["dedup"] and rows[1]["violations"] == 3
    assert rows[1]["makespan"] == rows[0]["makespan"] == 1.0
    stats = rs.meta["stats"]
    assert stats["solver_calls"] == 1
    assert stats["dedup_hits"] == 0  # unservable result: the twin is a miss
    assert stats["cache"]["misses"] == 1  # the twin; reps never probe


def test_campaign_accepts_json_literal_axes_and_skip():
    """The documented literal syntax (dicts for axes/skip, as in the README
    quickstart) must construct the same campaign as the typed objects."""
    lit = Campaign(
        name="lit",
        axes=[{"name": "size", "values": [5, 50]},
              {"name": "technique", "values": ["milp", "heft"]}],
        skip=[{"where": {"technique": "milp", "size": {"min": 26}},
               "reason": "size"}],
    )
    typed = Campaign(
        name="lit",
        axes=(Axis("size", (5, 50)), Axis("technique", ("milp", "heft"))),
        skip=(SkipRule(where={"technique": "milp", "size": {"min": 26}},
                       reason="size"),),
    )
    assert lit == typed
    assert [c.skipped for c in lit.expand()] == [None, None, "size", None]


def test_skip_and_failure_rows_keep_coordinates():
    c = Campaign(
        name="s",
        axes=(Axis("technique", ("heft", "milp")),),
        defaults={"family": "layered", "size": 4, "nodes": 2, "seed": 0},
        skip=(SkipRule(where={"technique": "milp"}, reason="size"),),
    )
    rs = run_campaign(c)
    rows = rs.rows()
    assert rows[0]["status"] == "ok"
    assert rows[1]["status"] == "skipped(size)"
    assert rows[1]["technique"] == "milp"  # coords survive the skip
    assert rows[1]["makespan"] is None
    assert rs.meta["stats"]["skipped"] == 1


def test_execute_option_adds_observed_columns():
    c = Campaign(
        name="x",
        axes=(Axis("technique", ("heft",)),),
        defaults={
            "family": "mri",
            "system": "mri",
            "perturbation": {"speed_factors": {"N2": 0.5}},
        },
        runner_options={"execute": True},
    )
    rs = run_campaign(c)
    r = rs.rows()[0]
    assert r["observed_makespan"] is not None
    assert r["slowdown"] is not None and r["slowdown"] >= 1.0


# ---------------------------------------------------------------------------
# ResultSet
# ---------------------------------------------------------------------------


def _sample_rs():
    rows = [
        {"cell": 0, "technique": "milp", "size": 5, "makespan": 10.0,
         "batched": False, "bucket": [8, 4], "note": None},
        {"cell": 1, "technique": "heft", "size": 5, "makespan": 10.5,
         "batched": False, "bucket": [8, 4], "note": "a,b\"quoted\""},
        {"cell": 2, "technique": "ga", "size": 5, "makespan": None,
         "batched": True, "bucket": None, "note": "x"},
    ]
    return ResultSet.from_rows(
        rows, name="t", meta={"coords": ["technique", "size"]}
    )


def test_resultset_json_round_trip():
    rs = _sample_rs()
    rt = ResultSet.from_json(json.loads(json.dumps(rs.to_json())))
    assert [c.to_json() for c in rt.columns] == [c.to_json() for c in rs.columns]
    assert rt.rows() == rs.rows()
    assert rt.meta == rs.meta
    assert rt.name == rs.name


def test_resultset_csv_round_trip():
    rs = _sample_rs()
    rt = ResultSet.from_csv(rs.to_csv(), name=rs.name, meta=rs.meta)
    assert rt.rows() == rs.rows()
    assert [c.dtype for c in rt.columns] == [c.dtype for c in rs.columns]


def test_mixed_numeric_axis_promotes_to_float():
    """An axis mixing ints and floats must not crash row collection after
    the cells were already solved: int promotes to float, other mixtures
    degrade to json."""
    rs = ResultSet.from_rows([{"x": 1, "y": 1}, {"x": 2.5, "y": "s"}])
    assert rs.dtype("x") == "float" and rs.column("x") == [1.0, 2.5]
    assert rs.dtype("y") == "json" and rs.column("y") == [1, "s"]


def test_resultset_typing_select_group_aggregate():
    rs = _sample_rs()
    assert rs.dtype("makespan") == "float"
    assert rs.dtype("batched") == "bool"
    assert rs.dtype("bucket") == "json"
    assert len(rs.select(technique=("milp", "ga"))) == 2
    groups = rs.group_by("size")
    assert len(groups) == 1 and len(groups[0][1]) == 3
    agg = rs.aggregate("makespan", by=("size",))
    row = agg.rows()[0]
    assert row["makespan_count"] == 2  # None excluded
    assert row["makespan_mean"] == pytest.approx(10.25)
    with pytest.raises(TypeError, match="is int"):
        ResultSet([Column("a", "int")], {"a": [1.5]})


def test_deviation_vs_exact_baseline():
    rows = []
    for size, exact_ms in ((5, 10.0), (10, 20.0)):
        rows += [
            {"technique": "milp", "size": size, "makespan": exact_ms},
            {"technique": "heft", "size": size, "makespan": exact_ms * 1.10},
            {"technique": "olb", "size": size, "makespan": exact_ms * 1.50},
        ]
    # a group with no exact baseline is kept, flagged "skipped" (a MILP
    # cell filtered by the size ceiling is the paper's '-' entry)
    rows.append({"technique": "heft", "size": 50, "makespan": 99.0})
    rs = ResultSet.from_rows(rows, meta={"coords": ["technique", "size"]})
    dev = rs.deviation_vs("milp")
    assert len(dev) == 7
    by_tech = {
        (r["technique"], r["size"]): r for r in dev
    }
    assert by_tech[("heft", 5)]["gap_pct"] == pytest.approx(10.0)
    assert by_tech[("heft", 5)]["baseline_status"] == "ok"
    assert by_tech[("olb", 10)]["gap_pct"] == pytest.approx(50.0)
    assert by_tech[("heft", 50)]["baseline_status"] == "skipped"
    assert by_tech[("heft", 50)]["gap_pct"] is None
    rep = rs.deviation_report("milp")
    rep_rows = {r["technique"]: r for r in rep}
    assert rep_rows["milp"]["gap_pct_mean"] == pytest.approx(0.0)
    assert rep_rows["heft"]["gap_pct_mean"] == pytest.approx(10.0)
    assert rep_rows["olb"]["gap_pct_max"] == pytest.approx(50.0)
    with pytest.raises(ValueError, match="within"):
        ResultSet.from_rows(rows).deviation_vs("milp")


# ---------------------------------------------------------------------------
# service runner
# ---------------------------------------------------------------------------


def test_service_runner_streams_grid_as_trace():
    c = Campaign(
        name="svc",
        axes=(Axis("seed", (0, 1, 0)),),  # third cell repeats the first
        defaults={
            "family": "layered",
            "size": 5,
            "nodes": 3,
            "technique": "heft",
            "system": "synthetic",
        },
        runner="service",
        runner_options={"arrival_spacing": 1.0, "batch_window": 0.25},
    )
    rs = run_campaign(c)
    rows = rs.rows()
    assert [r["status"] for r in rows] == ["completed"] * 3
    assert all(r["makespan"] is not None for r in rows)
    # identical content arriving later hits the service's solve cache
    assert rows[2]["cache_hit"] and not rows[0]["cache_hit"]
    assert rows[0]["makespan"] == rows[2]["makespan"]
    assert rs.meta["stats"]["summary"]["cache"]["hits"] >= 1


def test_service_runner_rejects_multi_workflow_and_mixed_systems():
    multi = Campaign(
        name="bad",
        axes=(Axis("technique", ("heft",)),),
        defaults={"family": "mri", "system": "mri"},
        runner="service",
    )
    with pytest.raises(ValueError, match="exactly one"):
        run_campaign(multi)
    mixed = Campaign(
        name="bad2",
        axes=(Axis("nodes", (2, 3)),),
        defaults={"family": "layered", "size": 4, "seed": 0,
                  "technique": "heft"},
        runner="service",
    )
    with pytest.raises(ValueError, match="one shared continuum"):
        run_campaign(mixed)
    # Submissions have no policy/perturbation/orchestration channel —
    # dropping those coords silently would run the wrong experiment
    unsupported = Campaign(
        name="bad3",
        axes=(Axis("policy", ({"rules": [], "final": "olb"},)),),
        defaults={"family": "layered", "size": 4, "nodes": 3, "seed": 0,
                  "technique": "policy"},
        runner="service",
    )
    with pytest.raises(ValueError, match="cannot honor"):
        run_campaign(unsupported)


def test_unknown_runner_suggests():
    with pytest.raises(KeyError, match="did you mean 'inline'"):
        run_campaign(_mini_campaign().replace(runner="inlin"))


# ---------------------------------------------------------------------------
# builtins + CLI
# ---------------------------------------------------------------------------


def test_builtin_campaigns_round_trip_and_example_spec_matches():
    for name in ("smoke", "table9", "service", "engine"):
        c = builtin_campaign(name)
        assert campaign_from_json(json.dumps(c.to_json())) == c
    example = Path(__file__).resolve().parent.parent / "examples" / "campaign_table9.json"
    assert campaign_from_json(example.read_text()) == builtin_campaign("table9")
    with pytest.raises(KeyError, match="did you mean 'smoke'"):
        builtin_campaign("smoek")


def test_cli_campaign_expand_run_report(tmp_path):
    spec = Campaign(
        name="cli",
        axes=(
            Axis("size", (4, 5)),
            Axis("technique", ("milp", "heft")),
        ),
        defaults={
            "family": "layered",
            "nodes": 3,
            "seed": 0,
            "engine": "auto",
            "solver_options": {"milp": {"time_limit": 5.0}},
        },
    )
    spec_path = spec.save(tmp_path / "spec.json")
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"}

    proc = subprocess.run(
        [sys.executable, "-m", "repro", "campaign", "expand", str(spec_path)],
        capture_output=True, text=True, env=env,
    )
    assert proc.returncode == 0, proc.stderr
    assert "# 4 cells (0 skipped), runner=inline" in proc.stdout

    out_path = tmp_path / "results.json"
    csv_path = tmp_path / "results.csv"
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "campaign", "run", str(spec_path),
         "--out", str(out_path), "--csv", str(csv_path)],
        capture_output=True, text=True, env=env,
    )
    assert proc.returncode == 0, proc.stderr
    assert "# deviation vs milp" in proc.stdout
    rs = ResultSet.load(out_path)
    assert len(rs) == 4
    assert all(r["status"] == "ok" for r in rs)
    saved_csv = ResultSet.from_csv(csv_path.read_text())
    assert saved_csv.rows() == rs.rows()

    proc = subprocess.run(
        [sys.executable, "-m", "repro", "campaign", "report", str(out_path),
         "--vs", "milp"],
        capture_output=True, text=True, env=env,
    )
    assert proc.returncode == 0, proc.stderr
    assert proc.stdout.splitlines()[0].startswith("technique,")
    techs = {line.split(",")[0] for line in proc.stdout.splitlines()[1:]}
    assert techs == {"milp", "heft"}

    # user errors exit cleanly with the did-you-mean message, no traceback
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "campaign", "run", str(spec_path),
         "--runner", "servce"],
        capture_output=True, text=True, env=env,
    )
    assert proc.returncode != 0
    assert "Traceback" not in proc.stderr
    assert "did you mean 'service'" in proc.stderr


# ---------------------------------------------------------------------------
# hypothesis-guarded property round-trips
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:
    _cell_values = st.one_of(
        st.none(),
        st.integers(min_value=-(2**31), max_value=2**31),
        st.floats(allow_nan=False, allow_infinity=False, width=32),
        st.booleans(),
        st.text(max_size=8),
    )

    @settings(max_examples=25, deadline=None)
    @given(
        st.lists(
            st.fixed_dictionaries(
                {},
                optional={
                    "a": st.integers(min_value=0, max_value=9),
                    "b": st.floats(allow_nan=False, allow_infinity=False,
                                   width=32),
                    "c": st.text(alphabet=st.characters(codec="utf-8",
                                                        exclude_characters="\r\n"),
                                 max_size=6),
                    "d": st.booleans(),
                },
            ),
            max_size=8,
        )
    )
    def test_resultset_json_round_trip_property(rows):
        rs = ResultSet.from_rows(rows, name="prop")
        rt = ResultSet.from_json(json.loads(json.dumps(rs.to_json())))
        assert rt.rows() == rs.rows()
        assert [c.to_json() for c in rt.columns] == [
            c.to_json() for c in rs.columns
        ]

    @settings(max_examples=15, deadline=None)
    @given(
        st.lists(st.sampled_from(["milp", "heft", "ga"]), min_size=1,
                 max_size=6),
        st.lists(st.integers(min_value=2, max_value=30), min_size=1,
                 max_size=4),
    )
    def test_expansion_is_product_and_stable_property(techniques, sizes):
        c = Campaign(
            name="p",
            axes=(
                Axis("technique", tuple(techniques)),
                Axis("size", tuple(sizes)),
            ),
        )
        cells = c.expand()
        assert len(cells) == len(techniques) * len(sizes)
        assert [x.coords for x in cells] == [x.coords for x in c.expand()]
        rt = campaign_from_json(json.dumps(c.to_json()))
        assert [x.coords for x in rt.expand()] == [x.coords for x in cells]
