"""Seeded continuum topology generator + jax digital-twin calibration.

Covers the `repro.topology` subsystem's invariants:

* determinism — same spec/seed ⇒ byte-identical System JSON (fuzzed),
* spec JSON round trip + strict parsing,
* tier invariants — counts, speed ranges, and the latency hierarchy
  (HPC island links > intra-HPC > any inter-tier path),
* System dtr validation fail-fast (NaN / negative / non-square) and the
  lossless +inf ↔ -1.0 JSON round trip,
* calibration recovery — 0.5–2.0× perturbed speeds fitted back within
  5% relative MAE, twin makespan error shrinking after calibration,
* integration — campaign `topology` axis, inline Scenario topology.
"""

import json

import numpy as np
import pytest

from repro.core import Workload, build_problem, random_layered_workflow
from repro.engine import pack
from repro.core.system_model import (
    System,
    make_system,
    mri_system,
    system_from_json,
    system_to_json,
)
from repro.topology import (
    LinkProfile,
    PRESETS,
    TierSpec,
    TopologySpec,
    cached_system,
    calibrate,
    calibration_report,
    generate,
    island_ids,
    least_squares_factors,
    perturbed_truth,
    resolve_spec,
    spec_from_json,
    synthesize_observations,
    tier_slices,
    tiered_spec,
)


def _system_bytes(system) -> bytes:
    return json.dumps(system_to_json(system), sort_keys=True).encode()


# ---------------------------------------------------------------------------
# spec validation + round trip
# ---------------------------------------------------------------------------


def test_link_profile_folds_latency_into_rate():
    # effective rate = S / (latency + S / bandwidth): latency-free links
    # saturate at the bandwidth, chatty links are dominated by latency
    ideal = LinkProfile(bandwidth=1.25)
    assert ideal.effective_rate(0.0625) == pytest.approx(1.25)
    wan = LinkProfile(bandwidth=1.25, latency=2e-2)
    assert wan.effective_rate(0.0625) < 1.25
    # smaller reference transfers pay proportionally more latency
    assert wan.effective_rate(0.001) < wan.effective_rate(0.0625)


def test_path_profile_chains_uplinks():
    spec = tiered_spec(1)
    iot, hpc = 0, 3
    path = spec.path_profile(iot, hpc)
    uplinks = [spec.tiers[i].uplink for i in range(iot, hpc)]
    assert path.bandwidth == min(u.bandwidth for u in uplinks)
    assert path.latency == pytest.approx(sum(u.latency for u in uplinks))
    # symmetric: same path class in both directions
    back = spec.path_profile(hpc, iot)
    assert back == path


def test_spec_json_round_trip_and_fingerprint():
    spec = tiered_spec(2, seed=11, name="rt")
    again = spec_from_json(spec.to_json())
    assert again == spec
    assert again.fingerprint() == spec.fingerprint()
    # bare header (no {"topology": ...} wrapper) parses too
    assert spec_from_json(spec.to_json()["topology"]) == spec
    # a spec edit changes the fingerprint
    assert spec.replace(seed=12).fingerprint() != spec.fingerprint()


def test_spec_validation_fails_fast():
    with pytest.raises(ValueError, match="at least one tier"):
        TopologySpec(name="empty", tiers=())
    tier = tiered_spec(1).tiers[0]
    with pytest.raises(ValueError, match="duplicate tier"):
        TopologySpec(name="dup", tiers=(tier, tier))
    with pytest.raises(ValueError, match="ref_transfer_mb"):
        TopologySpec(name="bad", tiers=(tier,), ref_transfer_mb=0.0)
    with pytest.raises(ValueError, match="island_link"):
        TierSpec(
            name="hpc", count=4, speed=(1.0, 2.0), cores=(8,),
            memory=(1.0, 2.0), features=("F1",),
            link=LinkProfile(bandwidth=1.0),
            uplink=LinkProfile(bandwidth=1.0),
            islands=2,  # islands > 1 without an island_link
        )
    with pytest.raises(ValueError, match="unknown"):
        spec_from_json({"name": "x", "tiers": [], "bogus": 1})


def test_resolve_spec_presets_and_errors():
    assert resolve_spec("tiny").num_nodes == 16
    assert resolve_spec("small").num_nodes == 64
    spec = tiered_spec(1)
    assert resolve_spec(spec) is spec
    assert resolve_spec(spec.to_json()) == spec
    assert resolve_spec(json.dumps(spec.to_json())) == spec
    with pytest.raises(ValueError, match="unknown topology preset"):
        resolve_spec("tinny")


# ---------------------------------------------------------------------------
# deterministic expansion
# ---------------------------------------------------------------------------


def test_generate_bit_identical_at_fixed_seed():
    spec = tiered_spec(2, seed=3)
    assert _system_bytes(generate(spec)) == _system_bytes(generate(spec))
    # a different seed reshuffles draws (jitter + speeds)
    other = generate(spec.replace(seed=4))
    assert _system_bytes(other) != _system_bytes(generate(spec))


def test_cached_system_memoizes_by_fingerprint():
    spec = tiered_spec(1, seed=9, name="memo")
    first = cached_system(spec)
    # an equal-but-distinct spec object maps to the same System instance
    assert cached_system(tiered_spec(1, seed=9, name="memo")) is first


def test_tier_invariants_small_preset():
    spec = PRESETS["small"]()
    system = generate(spec)
    slices = tier_slices(spec)
    assert system.num_nodes == spec.num_nodes == 64
    for tier in spec.tiers:
        sl = slices[tier.name]
        nodes = system.nodes[sl]
        assert len(nodes) == tier.count
        lo, hi = tier.speed
        for node in nodes:
            assert node.name.startswith(tier.name)
            assert lo <= node.properties["processing_speed"] <= hi
            assert node.resources["cores"] in tier.cores
            assert tier.memory[0] <= node.resources["memory"] <= tier.memory[1]
            assert frozenset(tier.features) == node.features

    # latency hierarchy: island links beat the HPC fabric, which beats
    # every cross-tier path (jitter is mean-preserving and small)
    isl = island_ids(spec)
    hpc = slices["hpc"]
    dtr = system.dtr
    same_island = (isl[:, None] == isl[None, :]) & (isl[:, None] >= 0)
    np.fill_diagonal(same_island, False)
    hpc_mask = np.zeros_like(same_island)
    hpc_mask[hpc, hpc] = True
    np.fill_diagonal(hpc_mask, False)
    intra_hpc = hpc_mask & ~same_island
    tier_of = np.repeat(
        np.arange(len(spec.tiers)), [t.count for t in spec.tiers]
    )
    inter_tier = tier_of[:, None] != tier_of[None, :]
    assert dtr[same_island].min() > dtr[intra_hpc].max()
    assert dtr[intra_hpc].min() > dtr[inter_tier].max()


def test_island_ids_contiguous_and_unique():
    spec = PRESETS["small"]()  # hpc tier: 8 nodes in 2 islands
    isl = island_ids(spec)
    hpc = tier_slices(spec)["hpc"]
    assert (isl[: hpc.start] == -1).all()  # only hpc is islanded
    hpc_ids = isl[hpc]
    assert set(hpc_ids) == {0, 1}
    assert (np.diff(hpc_ids) >= 0).all()  # contiguous blocks


# ---------------------------------------------------------------------------
# System dtr validation + lossless JSON round trip (satellite)
# ---------------------------------------------------------------------------


def _two_nodes():
    return mri_system().nodes[:2]


def test_system_rejects_bad_dtr():
    nodes = _two_nodes()
    with pytest.raises(ValueError, match="square"):
        make_system(nodes, np.ones((2, 3)))
    with pytest.raises(ValueError, match="NaN"):
        make_system(nodes, np.array([[np.inf, np.nan], [1.0, np.inf]]))
    with pytest.raises(ValueError, match="negative"):
        make_system(nodes, np.array([[np.inf, -0.5], [1.0, np.inf]]))


def test_system_json_rejects_ragged_dtr():
    obj = system_to_json(make_system(_two_nodes()))
    obj["dtr_matrix"][0] = obj["dtr_matrix"][0][:1]
    with pytest.raises(ValueError, match="square"):
        system_from_json(obj)


def test_system_json_round_trips_infinite_links():
    dtr = np.array([[np.inf, 0.125], [np.inf, np.inf]])  # dead 1→0 link
    system = make_system(_two_nodes(), dtr)
    obj = system_to_json(system)
    # JSON has no Infinity: encoded as the -1.0 sentinel...
    assert obj["dtr_matrix"][1][0] == -1.0
    # ...and decoded back to +inf, losslessly
    again = system_from_json(obj)
    assert np.array_equal(again.dtr, dtr)
    assert _system_bytes(again) == _system_bytes(system)


def test_generated_topology_round_trips_through_system_json():
    system = generate(tiered_spec(1, seed=5))
    assert _system_bytes(system_from_json(system_to_json(system))) == (
        _system_bytes(system)
    )


# ---------------------------------------------------------------------------
# digital-twin calibration
# ---------------------------------------------------------------------------


def _tiny_packed():
    system = generate(tiered_spec(1, seed=2))
    wf = random_layered_workflow(
        24, name="probe", seed=24, max_cores=4, feature_pool=("F1",)
    )
    workload = Workload((wf,))
    return system, workload, pack(build_problem(system, workload), pad=False)


def test_calibration_recovers_perturbed_speeds_within_5pct():
    system, _, packed = _tiny_packed()
    _, f_true, _ = perturbed_truth(system, seed=7, link_range=(1.0, 1.0))
    obs = synthesize_observations(
        packed, speed_factors=f_true, samples_per_node=32, noise=0.05, seed=8
    )
    result = calibrate(packed, obs, steps=300)
    covered = result.coverage > 0
    assert covered.all()  # every node drew samples
    rel = np.abs(result.speed_factors[covered] / f_true[covered] - 1.0)
    assert rel.mean() < 0.05
    # GD converged onto the closed-form separable optimum
    np.testing.assert_allclose(
        result.speed_factors, result.baseline_speed_factors, rtol=1e-3
    )
    assert result.loss[1] < result.loss[0]


def test_least_squares_shrinks_unobserved_nodes_to_one():
    _, _, packed = _tiny_packed()
    n = packed.num_nodes
    f_true = np.full(n, 2.0)
    obs = synthesize_observations(
        packed, speed_factors=f_true, samples_per_node=4, noise=0.0, seed=1
    )
    # keep observations for node 0 only
    keep = obs.node == 0
    import dataclasses

    pruned = dataclasses.replace(
        obs,
        task=obs.task[keep],
        node=obs.node[keep],
        duration=obs.duration[keep],
    )
    f, _ = least_squares_factors(packed, pruned, l2=1e-6)
    assert f[0] == pytest.approx(2.0, rel=1e-2)
    np.testing.assert_allclose(f[1:], 1.0)


def test_calibration_report_shrinks_twin_error():
    system, workload, _ = _tiny_packed()
    report = calibration_report(
        system, workload, perturb_seed=7, samples_per_node=32,
        noise=0.05, steps=300,
    )
    assert report["nodes"] == 16
    assert report["speed_factor_rel_mae"] < 0.05
    assert report["twin_error_after"] < report["twin_error_before"]
    assert report["twin_error_after"] < 0.05
    # the fitted factors beat (or match) nothing-fitted by construction;
    # the closed-form baseline is in the same band as the GD fit
    assert report["baseline_rel_mae"] < 0.10


# ---------------------------------------------------------------------------
# integration: campaign axis + inline Scenario topology
# ---------------------------------------------------------------------------


def test_cell_system_topology_axis():
    from repro.campaigns.spec import cell_system

    system = cell_system({"system": "topology", "topology": "tiny"})
    assert system is cached_system(resolve_spec("tiny"))
    inline = tiered_spec(1, seed=21).to_json()
    assert cell_system({"system": "topology", "topology": inline}).num_nodes == 16
    with pytest.raises(ValueError, match="'topology' coordinate"):
        cell_system({"system": "topology"})


def test_scenario_inline_topology():
    from repro.core.api import scenario_from_json

    wf_section = {
        "t1": {"work": 1.0, "resources": {"cores": 1}, "features": ["F1"]}
    }
    scenario = scenario_from_json(
        {
            "scenario": {"name": "topo", "technique": "heft"},
            "topology": tiered_spec(1, seed=13).to_json()["topology"],
            "wf": {"tasks": wf_section},
        }
    )
    assert scenario.system.num_nodes == 16
    with pytest.raises(ValueError, match="pick one system source"):
        scenario_from_json(
            {
                "scenario": {"name": "topo"},
                "nodes": system_to_json(mri_system())["nodes"],
                "topology": "tiny",
                "wf": {"tasks": wf_section},
            }
        )


# ---------------------------------------------------------------------------
# hypothesis fuzz (optional dependency, mirrored from test_property.py)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - container without hypothesis
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        scale=st.integers(min_value=1, max_value=4),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_topology_expansion_deterministic(scale, seed):
        spec = tiered_spec(scale, seed=seed)
        a, b = generate(spec), generate(spec)
        assert _system_bytes(a) == _system_bytes(b)
        assert a.num_nodes == 16 * scale
        # spec JSON survives a round trip under fuzzed parameters too
        assert spec_from_json(spec.to_json()) == spec

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_topology_dtr_always_valid(seed):
        system = generate(tiered_spec(1, seed=seed))
        off = ~np.eye(system.num_nodes, dtype=bool)
        assert np.isfinite(system.dtr[off]).all()
        assert (system.dtr[off] > 0).all()
        assert np.isinf(np.diag(system.dtr)).all()
