"""Serving engine, Snakemake I/O (Fig 5/6 dialect), continuum job scheduling,
autoshard roofline estimates, monitor feedback loop."""

import dataclasses
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    ObjectiveWeights,
    build_problem,
    mri_system,
    mri_workload,
    solve_problem,
    verify_schedule,
    Workload,
)
from repro.core.autoshard import Layout, best_layout, estimate, kv_cache_bytes
from repro.core.continuum import (
    Job,
    default_job_mix,
    schedule_jobs,
    training_step_workflow,
)
from repro.core.monitor import MonitorState
from repro.core.simulator import execute
from repro.core.snakemake_io import load_config, parse_rules
from repro.configs.shapes import SHAPES
from repro.models.registry import get_model
from repro.serve.engine import EngineConfig, Request, ServeEngine

FIG6_SNAKEFILE = """
rule T1: # dependencies
 input:
 experiment.conf
 output:
 product1.dat
 resources:
 mem_mb = [1024] # memory_required, (R2)
 features = ["F1", "F2"] # requested features
 data = 2GiB # estimated output size, (R3)
 duration = [1000] # usage, in seconds
 run:
 # Execute shell command/script

rule T2:
 input:
 product1.dat
 output:
 product2.dat
 resources:
 features = ["F1"]
"""


def test_parse_fig6_rules():
    wf = parse_rules(FIG6_SNAKEFILE)
    assert [t.name for t in wf.tasks] == ["T1", "T2"]
    t1, t2 = wf.tasks
    assert t1.memory == 1024
    assert t1.features == {"F1", "F2"}
    assert t1.data == 2.0
    assert t1.work == 1000.0
    assert t2.deps == ("T1",)  # inferred from product1.dat


def test_schedule_json_contract(tmp_path):
    prob = build_problem(mri_system(), mri_workload())
    rep = solve_problem(prob, "heft")
    obj = rep.schedule.to_json(prob, [n.name for n in mri_system().nodes])
    assert obj["makespan"] > 0
    assert len(obj["schedule"]) == prob.num_tasks
    # sorted by start time
    starts = [e["start"] for e in obj["schedule"]]
    assert starts == sorted(starts)
    path = tmp_path / "sched.json"
    path.write_text(json.dumps(obj))
    assert json.loads(path.read_text())["technique"] == "heft"


def test_load_combined_config(tmp_path):
    obj = {
        "nodes": {"N1": {"cores": [4], "features": ["F1"],
                         "processing_speed": [1.0], "data_transfer_rate": [10]}},
        "Workflow 1": {"tasks": {"T1": {"cores": [1], "duration": [5],
                                        "features": ["F1"], "dependencies": []}}},
    }
    p = tmp_path / "config.json"
    p.write_text(json.dumps(obj))
    system, workload = load_config(p)
    assert system.num_nodes == 1
    assert workload.num_tasks == 1


def test_monitor_feedback_improves_prediction():
    """Fig. 4 loop: solve → execute (slow node) → monitor updates P →
    re-solve predicts the observed reality."""
    system = mri_system()
    prob = build_problem(system, mri_workload())
    rep = solve_problem(prob, "heft")
    slow = np.array([1.0, 0.5, 1.0])  # N2 at half speed
    run1 = execute(prob, rep.schedule, speed_factors=slow)
    assert run1.slowdown > 1.2

    mon = MonitorState(smoothing=1.0)
    mon.update(system, prob, run1)
    system2 = mon.refreshed_system(system)
    assert system2.nodes[1].processing_speed == pytest.approx(0.5, rel=1e-6)


# ---------------------------------------------------------------------------
# continuum / autoshard
# ---------------------------------------------------------------------------

def test_roofline_estimates_sane():
    cfg = get_model("deepseek-67b").config
    est = estimate(cfg, SHAPES["train_4k"], Layout(dp=16, tp=16))
    assert est.compute_s > 0 and est.memory_s > 0
    assert est.bottleneck in ("compute", "memory", "collective")
    # training a 67B dense model at 1M tokens/step on 256 v5e chips: the
    # compute term must be O(10 s), not O(ms) or O(hours)
    assert 1.0 < est.compute_s < 100.0


def test_decode_is_memory_bound():
    cfg = get_model("qwen2.5-3b").config
    est = estimate(cfg, SHAPES["decode_32k"], Layout(dp=16, tp=16))
    assert est.bottleneck == "memory"  # decode streams params+KV


def test_kv_bytes_window_bounded():
    g = get_model("gemma2-2b").config
    q = get_model("qwen2.5-3b").config
    # gemma2 local layers cap their KV at the window — much smaller than a
    # same-depth full-attention model at 512k
    assert kv_cache_bytes(g, 1, 524288) < 0.7 * kv_cache_bytes(q, 1, 524288) * (26 / 36) * 4


def test_best_layout_respects_hbm():
    cfg = get_model("deepseek-67b").config
    lay, est = best_layout(cfg, SHAPES["train_4k"], chips=256)
    assert est.hbm_per_chip <= 16 * 1024**3


def test_schedule_jobs_end_to_end():
    rep, system = schedule_jobs(technique="heft")
    assert rep.schedule.violations == 0
    assert verify_schedule(rep.problem, rep.schedule) == []
    assert np.isfinite(rep.schedule.makespan)
    # dependencies (train → serve) respected is covered by verify_schedule


def test_training_step_workflow_dag():
    wf = training_step_workflow("qwen2.5-3b", groups=4)
    assert wf.num_tasks == 4 + 4 + 1
    names = {t.name: t for t in wf.tasks}
    assert "fwd0" in names["bwd0"].deps or "bwd1" in names["bwd0"].deps
    assert len(names["update"].deps) == 4


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_engine_matches_manual_decode():
    api = get_model("qwen2.5-3b")
    cfg = api.reduced
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompt = np.array([3, 1, 4, 1, 5], dtype=np.int32)

    # manual greedy: prefill + decode
    cache = api.init_cache(1, 64, cfg)
    lg, cache = api.prefill(params, jnp.asarray(prompt)[None], cache, cfg)
    expected = [int(jnp.argmax(lg[0]))]
    for _ in range(4):
        lg, cache = api.decode_step(params, jnp.asarray([expected[-1]], jnp.int32), cache, cfg)
        expected.append(int(jnp.argmax(lg[0])))

    eng = ServeEngine(api, cfg, params, EngineConfig(max_slots=2, max_len=64))
    req = Request(rid=0, prompt=prompt, max_new_tokens=5)
    eng.submit(req)
    eng.run_until_done()
    assert req.done
    assert req.output == expected


def test_engine_batches_multiple_requests():
    api = get_model("qwen2.5-3b")
    cfg = api.reduced
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(api, cfg, params, EngineConfig(max_slots=2, max_len=64))
    reqs = [Request(rid=i, prompt=np.arange(3 + i, dtype=np.int32) % cfg.vocab,
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 4 for r in reqs)
