"""Evaluator semantics + solver correctness: the Table VI reproduction, the
capacity co-running case, MILP optimality vs heuristics, MH convergence."""

import numpy as np
import pytest

from repro.core import (
    ObjectiveWeights,
    Task,
    Workflow,
    Workload,
    build_problem,
    evaluate_assignment,
    mri_system,
    mri_w1,
    mri_w2,
    mri_workload,
    verify_schedule,
)
from repro.core.evaluator import make_fitness_fn
from repro.core.heuristics import heft, olb, upward_ranks
from repro.core.metaheuristics import aco, ga, pso, sa
from repro.core.milp import MilpSizeError, solve_milp
from repro.core.simulator import execute


# ---------------------------------------------------------------------------
# evaluator semantics
# ---------------------------------------------------------------------------

def test_serial_chain_timing():
    """W1 all on N2: 3 + 5 + 2 = 10 with zero transfers."""
    prob = build_problem(mri_system(), Workload((mri_w1(),)))
    s = evaluate_assignment(prob, np.array([1, 1, 1]))
    assert s.makespan == pytest.approx(10.0)
    assert s.violations == 0
    assert list(s.start) == [0.0, 3.0, 8.0]


def test_cross_node_transfer_added():
    """T1 on N1, rest on N2: T2 waits for the 0.02 transfer (Eq. 5/12)."""
    prob = build_problem(mri_system(), Workload((mri_w1(),)))
    s = evaluate_assignment(prob, np.array([0, 1, 1]))
    assert s.start[1] == pytest.approx(3.02)
    assert s.makespan == pytest.approx(10.02)


def test_capacity_corun_allowed():
    """W2's T2 (12 cores) and T3 (32 cores) co-run on N2 (48 cores) — the
    paper's Table VI schedule requires this."""
    prob = build_problem(mri_system(), Workload((mri_w2(),)))
    s = evaluate_assignment(prob, np.array([1, 1, 1, 1]))
    assert s.start[1] == pytest.approx(3.0)
    assert s.start[2] == pytest.approx(3.0)  # co-runs with T2
    assert s.makespan == pytest.approx(10.0)


def test_capacity_exceeded_serializes():
    """Two 32-core tasks on 48-core N2 cannot co-run."""
    sys_ = mri_system()
    wf = Workflow("w", (
        Task("a", cores=32, work=0, durations={"N1": 2, "N2": 2, "N3": 2}),
        Task("b", cores=32, work=0, durations={"N1": 2, "N2": 2, "N3": 2}),
    ))
    prob = build_problem(sys_, Workload((wf,)))
    s = evaluate_assignment(prob, np.array([1, 1]))
    assert s.makespan == pytest.approx(4.0)  # serialized
    s3 = evaluate_assignment(prob, np.array([2, 2]))
    assert s3.makespan == pytest.approx(2.0)  # N3 has 2572 cores → co-run


def test_infeasible_assignment_penalized():
    prob = build_problem(mri_system(), Workload((mri_w1(),)))
    s = evaluate_assignment(prob, np.array([0, 0, 0]))  # T2/T3 need F2
    assert s.violations == 2
    assert s.objective > 1e8


def test_jax_fitness_matches_oracle():
    prob = build_problem(mri_system(), mri_workload())
    fit = make_fitness_fn(prob)
    rng = np.random.default_rng(0)
    A = rng.integers(0, prob.num_nodes, (32, prob.num_tasks))
    obj, mk = fit(A)
    for k in range(32):
        ref = evaluate_assignment(prob, A[k])
        assert float(mk[k]) == pytest.approx(ref.makespan, rel=1e-4)
        assert float(obj[k]) == pytest.approx(ref.objective, rel=1e-4)


# ---------------------------------------------------------------------------
# MILP — Algorithm 1
# ---------------------------------------------------------------------------

def test_milp_reproduces_table6_w1():
    prob = build_problem(mri_system(), Workload((mri_w1(),)))
    s = solve_milp(prob)
    assert s.status == "optimal"
    assert s.makespan == pytest.approx(10.0, abs=1e-5)
    assert s.usage == pytest.approx(32.0)
    assert verify_schedule(prob, s) == []


def test_milp_reproduces_table6_w2():
    prob = build_problem(mri_system(), Workload((mri_w2(),)))
    s = solve_milp(prob)
    assert s.status == "optimal"
    assert s.makespan == pytest.approx(10.0, abs=1e-5)
    assert s.usage == pytest.approx(64.0)
    assert verify_schedule(prob, s) == []


def test_milp_static_mode_matches_paper_capacity():
    """Paper-faithful Eq. (10): ΣU per node ≤ R_i forces W2 to spread."""
    prob = build_problem(mri_system(), Workload((mri_w2(),)))
    s = solve_milp(prob, capacity_mode="static")
    assert s.status == "optimal"
    # usage on any node must respect the static budget
    for i in range(prob.num_nodes):
        used = prob.usage[s.assignment == i].sum()
        assert used <= prob.node_cores[i] + 1e-6
    assert s.makespan == pytest.approx(10.0, abs=1e-4)


def test_milp_size_guard():
    from repro.core import synthetic_workload

    prob = build_problem(mri_system(), synthetic_workload(100, seed=1))
    with pytest.raises(MilpSizeError):
        solve_milp(prob, max_tasks=60)


def test_milp_respects_release_times():
    wf = Workflow("w", (Task("a", cores=1, work=0, durations={"N1": 1, "N2": 1, "N3": 1}),),
                  submission=4.0)
    prob = build_problem(mri_system(), Workload((wf,)))
    s = solve_milp(prob)
    assert s.start[0] >= 4.0 - 1e-6
    assert s.makespan >= 5.0 - 1e-6


# ---------------------------------------------------------------------------
# heuristics + metaheuristics
# ---------------------------------------------------------------------------

def test_heft_ranks_decrease_along_edges():
    prob = build_problem(mri_system(), mri_workload())
    rank = upward_ranks(prob)
    for p, j in prob.edges:
        assert rank[p] > rank[j]


@pytest.mark.parametrize("fn", [heft, olb])
def test_heuristics_valid_and_near_optimal(fn):
    prob = build_problem(mri_system(), mri_workload())
    s = fn(prob)
    assert verify_schedule(prob, s) == []
    assert s.violations == 0
    assert s.makespan <= 10.0 * 1.15  # paper: 5–10 % deviation band


@pytest.mark.parametrize("fn,kw", [
    (ga, dict(pop_size=32, generations=30)),
    (pso, dict(pop_size=32, iterations=30)),
    (sa, dict(chains=16, steps=120)),
    (aco, dict(ants=32, iterations=30)),
])
def test_metaheuristics_find_mri_optimum(fn, kw):
    prob = build_problem(mri_system(), mri_workload())
    res = fn(prob, seed=0, **kw)
    s = res.schedule
    assert verify_schedule(prob, s) == []
    assert s.violations == 0
    assert s.makespan <= 10.0 + 0.25  # within the paper's deviation band
    assert len(res.history) > 0
    # best objective is monotonically improving for elitist methods
    assert res.history[-1] <= res.history[0] + 1e-6


def test_executor_replay_matches_oracle():
    prob = build_problem(mri_system(), mri_workload())
    s = evaluate_assignment(prob, np.array([1, 1, 1, 1, 1, 1, 1]))
    rep = execute(prob, s)
    assert rep.makespan == pytest.approx(s.makespan)
    assert rep.slowdown == pytest.approx(1.0)


def test_executor_detects_slow_node():
    prob = build_problem(mri_system(), mri_workload())
    s = evaluate_assignment(prob, np.array([1, 1, 1, 1, 1, 1, 1]))
    rep = execute(prob, s, speed_factors=np.array([1.0, 0.5, 1.0]))
    assert rep.makespan > s.makespan * 1.5
    factors = rep.observed_speed_factors(prob)
    assert factors[1] == pytest.approx(0.5, rel=1e-6)
