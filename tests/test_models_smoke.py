"""Per-architecture smoke tests on REDUCED configs (the full configs are
exercised only via the dry-run): one forward + one train step on CPU with
shape and NaN assertions, plus prefill/decode consistency per family."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.registry import ALL_ARCHS, get_model
from repro.optim import adamw
from repro.train.train_step import make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = (
            jax.random.normal(jax.random.PRNGKey(8), (B, cfg.enc_frames, cfg.d_model)) * 0.1
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["patches"] = (
            jax.random.normal(jax.random.PRNGKey(9), (B, cfg.num_patches, cfg.d_model)) * 0.1
        ).astype(jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nans(arch):
    api = get_model(arch)
    cfg = api.reduced
    params = api.init(KEY, cfg)
    B, S = 2, 32
    logits, aux = api.forward(params, _batch(cfg, B, S), cfg)
    prefix = cfg.num_patches if cfg.family == "vlm" else 0
    assert logits.shape == (B, S + prefix, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux["aux_loss"]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    api = get_model(arch)
    cfg = api.reduced
    params = api.init(KEY, cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state = adamw.init(opt_cfg, params)
    step = jax.jit(make_train_step(api, cfg, opt_cfg, remat=True))
    params2, opt_state2, metrics = step(params, opt_state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved
    assert int(opt_state2["step"]) == 1


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "gemma2-2b", "mixtral-8x7b",
                                  "qwen3-moe-30b-a3b", "stablelm-1.6b", "internvl2-76b"])
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the full-forward logits.

    MoE configs use a lossless capacity factor here: with token dropping,
    forward(S) and prefill(S/2) legitimately drop different tokens —
    equivalence only holds when no token is dropped."""
    import dataclasses

    api = get_model(arch)
    cfg = api.reduced
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    params = api.init(KEY, cfg)
    B, S, split = 2, 16, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = _batch(cfg, B, S)
    batch["tokens"] = toks
    logits_full, _ = api.forward(params, batch, cfg)
    prefix = cfg.num_patches if cfg.family == "vlm" else 0

    cache = api.init_cache(B, 64, cfg)
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = batch["patches"]
    lg, cache = api.prefill(params, toks[:, :split], cache, cfg, **extras)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(logits_full[:, prefix + split - 1]),
        rtol=5e-2, atol=5e-2,
    )
    for t in range(split, S):
        lg, cache = api.decode_step(params, toks[:, t], cache, cfg)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, prefix + t]),
            rtol=5e-2, atol=5e-2,
        )


@pytest.mark.parametrize("arch", ["mamba2-780m", "zamba2-7b"])
def test_ssm_prefill_decode_matches_forward(arch):
    api = get_model(arch)
    cfg = api.reduced
    params = api.init(KEY, cfg)
    B, S, split = 2, 16, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    logits_full, _ = api.forward(params, {"tokens": toks}, cfg)
    cache = api.init_cache(B, 64, cfg)
    lg, cache = api.prefill(params, toks[:, :split], cache, cfg)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(logits_full[:, split - 1]), rtol=5e-2, atol=5e-2
    )
    for t in range(split, S):
        lg, cache = api.decode_step(params, toks[:, t], cache, cfg)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, t]), rtol=5e-2, atol=5e-2
        )


def test_whisper_prefill_decode_matches_forward():
    api = get_model("whisper-base")
    cfg = api.reduced
    params = api.init(KEY, cfg)
    B, S, split = 2, 12, 6
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    frames = (jax.random.normal(jax.random.PRNGKey(4), (B, cfg.enc_frames, cfg.d_model)) * 0.1
              ).astype(jnp.dtype(cfg.dtype))
    logits_full, _ = api.forward(params, {"tokens": toks, "frames": frames}, cfg)
    cache = api.init_cache(B, 64, cfg)
    lg, cache = api.prefill(params, toks[:, :split], cache, cfg, frames=frames)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(logits_full[:, split - 1]), rtol=5e-2, atol=5e-2
    )
    for t in range(split, S):
        lg, cache = api.decode_step(params, toks[:, t], cache, cfg)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, t]), rtol=5e-2, atol=5e-2
        )


def test_gemma2_window_bounds_cache():
    """gemma2 local layers must allocate window-sized (not S-sized) caches."""
    api = get_model("gemma2-2b")
    cfg = api.reduced  # window=8
    cache = api.init_cache(2, 64, cfg)
    local_kv, global_kv = cache["kv"]
    assert local_kv["k"].shape[3] == cfg.window
    assert global_kv["k"].shape[3] == 64


def test_sliding_window_ring_buffer_decode():
    """mixtral-style SWA: decode past the window stays correct vs a full
    forward restricted to the window."""
    import dataclasses

    api = get_model("mixtral-8x7b")
    cfg = dataclasses.replace(api.reduced, capacity_factor=64.0)  # window=8, lossless MoE
    params = api.init(KEY, cfg)
    B, S = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab)
    logits_full, _ = api.forward(params, {"tokens": toks}, cfg)
    cache = api.init_cache(B, 16, cfg)  # cache smaller than S → ring wraps
    lg, cache = api.prefill(params, toks[:, :12], cache, cfg)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, 11]),
                               rtol=5e-2, atol=5e-2)
    for t in range(12, S):
        lg, cache = api.decode_step(params, toks[:, t], cache, cfg)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, t]),
                                   rtol=5e-2, atol=5e-2)


def test_param_counts_match_analytic():
    for arch in ALL_ARCHS:
        api = get_model(arch)
        cfg = api.reduced
        params = api.init(KEY, cfg)
        n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        assert n == cfg.param_count(), arch


def test_full_config_param_counts_in_range():
    expected = {
        "qwen2.5-3b": (3.0e9, 3.8e9),
        "stablelm-1.6b": (1.4e9, 1.9e9),
        "deepseek-67b": (64e9, 70e9),
        "gemma2-2b": (2.2e9, 3.2e9),
        "whisper-base": (0.05e9, 0.11e9),
        "mamba2-780m": (0.7e9, 0.85e9),
        "qwen3-moe-30b-a3b": (29e9, 32e9),
        "mixtral-8x7b": (45e9, 48e9),
        "zamba2-7b": (6.0e9, 8.0e9),
        "internvl2-76b": (68e9, 73e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_model(arch).config.param_count()
        assert lo <= n <= hi, (arch, n)
    # MoE active counts
    assert 2.5e9 <= get_model("qwen3-moe-30b-a3b").config.active_param_count() <= 4e9
    assert 12e9 <= get_model("mixtral-8x7b").config.active_param_count() <= 14e9
