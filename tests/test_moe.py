"""MoE dispatch correctness: capacity dispatch vs the dense oracle, aux
load-balance loss, capacity math, drop behaviour."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.moe import moe_capacity, moe_ffn, moe_ffn_dense_ref, moe_init

CFG = ModelConfig(
    name="t", family="moe", num_layers=1, d_model=32, vocab=64,
    num_heads=4, num_kv_heads=2, head_dim=8,
    num_experts=8, top_k=2, d_ff_expert=16, capacity_factor=64.0,  # lossless
)


def _setup(cfg=CFG, B=2, S=16, seed=0):
    params = moe_init(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, cfg.d_model)) * 0.5
    return params, x


def test_lossless_capacity_matches_dense_oracle():
    params, x = _setup()
    y, aux = moe_ffn(params, x, CFG)
    y_ref = moe_ffn_dense_ref(params, x, CFG)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5, rtol=1e-5)


def test_gates_renormalized():
    """Top-k gate weights sum to 1 → output magnitude independent of k."""
    params, x = _setup()
    cfg1 = dataclasses.replace(CFG, top_k=1)
    y1, _ = moe_ffn(params, x, cfg1)
    assert np.isfinite(np.asarray(y1)).all()


def test_aux_loss_uniform_router_is_one_coef():
    """With a perfectly uniform router, aux = coef · E · Σ (1/E · 1/E) · E = coef."""
    cfg = dataclasses.replace(CFG, aux_loss_coef=0.01)
    params, x = _setup(cfg)
    params = {**params, "router": {"w": jnp.zeros_like(params["router"]["w"])}}
    _, aux = moe_ffn(params, x, cfg)
    # uniform probs → me = 1/E; top-1 ties broken deterministically → ce is
    # a one-hot distribution; aux = coef·E·Σ me·ce = coef·E·(1/E) = coef
    assert float(aux) == pytest.approx(0.01, rel=1e-3)


def test_capacity_dropping_bounds_work():
    """With capacity_factor=1.0, per-expert tokens ≤ C and output stays finite."""
    cfg = dataclasses.replace(CFG, capacity_factor=1.0)
    params, x = _setup(cfg, B=4, S=32)
    y, aux = moe_ffn(params, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    # dropped tokens pass through with zero MoE contribution — y can differ
    y_ref = moe_ffn_dense_ref(params, x, cfg)
    assert not np.allclose(np.asarray(y), np.asarray(y_ref))


def test_moe_capacity_rounding():
    cfg = dataclasses.replace(CFG, capacity_factor=1.25)
    c = moe_capacity(cfg, 1024)
    assert c >= 1024 * cfg.top_k * 1.25 / cfg.num_experts
    assert c % 8 == 0


def test_dispatch_permutation_invariance():
    """Shuffling tokens then unshuffling gives the same outputs (lossless
    capacity) — the sort-based dispatch must not couple tokens."""
    params, x = _setup()
    B, S, d = x.shape
    y, _ = moe_ffn(params, x, CFG)
    perm = jax.random.permutation(jax.random.PRNGKey(9), S)
    y_p, _ = moe_ffn(params, x[:, perm], CFG)
    np.testing.assert_allclose(
        np.asarray(y[:, perm]), np.asarray(y_p), atol=1e-5, rtol=1e-5
    )
