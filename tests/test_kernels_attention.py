"""Flash / decode attention Pallas kernels vs jnp oracles — shape, dtype,
GQA-group, masking and softcap sweeps (interpret mode)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("B,H,Hkv,S,D", [
    (1, 4, 4, 128, 32),    # MHA
    (2, 4, 2, 128, 64),    # GQA 2x
    (1, 8, 2, 256, 32),    # GQA 4x
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_shapes_dtypes(B, H, Hkv, S, D, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(k1, (B, H, S, D), dtype)
    k = _rand(k2, (B, Hkv, S, D), dtype)
    v = _rand(k3, (B, Hkv, S, D), dtype)
    out = flash_attention_pallas(q, k, v, block_q=64, block_k=64)
    exp = ref.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("kw", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=32),       # SWA (mixtral) / local (gemma2)
    dict(causal=True, window=64),
    dict(causal=True, softcap=50.0),    # gemma2 logit softcap
    dict(causal=True, window=32, softcap=50.0),
])
def test_flash_masking_modes(kw):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(k1, (2, 4, 128, 32), jnp.float32)
    k = _rand(k2, (2, 2, 128, 32), jnp.float32)
    v = _rand(k3, (2, 2, 128, 32), jnp.float32)
    out = flash_attention_pallas(q, k, v, block_q=32, block_k=32, **kw)
    exp = ref.flash_attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


def test_flash_kv_longer_than_q():
    """Chunked prefill: Skv > Sq with the causal offset."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(k1, (1, 2, 64, 32), jnp.float32)
    k = _rand(k2, (1, 2, 256, 32), jnp.float32)
    v = _rand(k3, (1, 2, 256, 32), jnp.float32)
    out = flash_attention_pallas(q, k, v, block_q=32, block_k=64)
    exp = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


def test_blockwise_jnp_path_matches_ref():
    """The dry-run's lax.map blockwise attention == dense reference."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(k1, (1, 4, 1024, 32), jnp.float32)
    k = _rand(k2, (1, 2, 1024, 32), jnp.float32)
    v = _rand(k3, (1, 2, 1024, 32), jnp.float32)
    out = ops._blockwise_attention_jnp(
        q, k, v, causal=True, window=None, softcap=None, scale=None, block_q=256
    )
    exp = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,H,Hkv,S,D", [
    (1, 4, 4, 256, 32),
    (3, 8, 2, 512, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_shapes_dtypes(B, H, Hkv, S, D, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(4), 3)
    q = _rand(k1, (B, H, D), dtype)
    kc = _rand(k2, (B, Hkv, S, D), dtype)
    vc = _rand(k3, (B, Hkv, S, D), dtype)
    lengths = jnp.asarray([S] + [S // 3] * (B - 1), jnp.int32)[:B]
    out = decode_attention_pallas(q, kc, vc, lengths, block_k=128)
    exp = ref.decode_attention_ref(q, kc, vc, lengths)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=tol, rtol=tol
    )


def test_decode_length_one():
    """Fresh cache with a single valid entry."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    q = _rand(k1, (2, 4, 32), jnp.float32)
    kc = _rand(k2, (2, 2, 128, 32), jnp.float32)
    vc = _rand(k3, (2, 2, 128, 32), jnp.float32)
    lengths = jnp.asarray([1, 1], jnp.int32)
    out = decode_attention_pallas(q, kc, vc, lengths, block_k=64)
    exp = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)
