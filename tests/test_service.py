"""Event-driven scheduling service: event-loop ordering, replay determinism,
the solve-cache hot path (zero solver invocations on repeats), admission
batching, node drift/failure handling, trace I/O, and the serve CLI."""

import json
import math
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import Task, Workflow, make_system, Node
from repro.core.workload_model import mri_w1
from repro.service import (
    EventLoop,
    SchedulingService,
    ServiceConfig,
    Submission,
    Trace,
    continuum_system,
    generate_trace,
    load_trace,
    trace_from_json,
)
from repro.service.traces import NodeEvent


# ---------------------------------------------------------------------------
# event loop
# ---------------------------------------------------------------------------

def test_event_loop_orders_by_time_then_push_order():
    loop = EventLoop()
    loop.push(5.0, "b")
    loop.push(1.0, "a")
    loop.push(5.0, "c")  # same time as "b": push order breaks the tie
    kinds = [ev.kind for ev in loop.drain()]
    assert kinds == ["a", "b", "c"]
    assert loop.now == 5.0


def test_event_loop_clamps_past_pushes_to_now():
    loop = EventLoop()
    loop.push(10.0, "later")
    assert loop.pop().kind == "later"
    ev = loop.push(3.0, "too-early")  # in the past: clamps to now
    assert ev.time == 10.0
    assert loop.pop().time == 10.0


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

def _single_node_system(speed: float = 1.0):
    return make_system([
        Node("N1", {"cores": 8}, frozenset({"F1"}),
             {"processing_speed": speed, "data_transfer_rate": 100.0}),
    ])


def _two_node_system():
    return make_system([
        Node("N1", {"cores": 8}, frozenset({"F1"}),
             {"processing_speed": 1.0, "data_transfer_rate": 100.0}),
        Node("N2", {"cores": 8}, frozenset({"F1"}),
             {"processing_speed": 4.0, "data_transfer_rate": 100.0}),
    ])


def _chain(name: str, works) -> Workflow:
    tasks = [
        Task(
            f"T{i}",
            cores=2,
            work=float(w),
            features=frozenset({"F1"}),
            deps=(f"T{i - 1}",) if i else (),
        )
        for i, w in enumerate(works)
    ]
    return Workflow(name, tuple(tasks))


def _sub(i, wf, t, technique="heft", **kw) -> Submission:
    return Submission(
        id=f"s{i:03d}", tenant="t0", time=float(t), family="test",
        workflow=wf, technique=technique, **kw,
    )


# ---------------------------------------------------------------------------
# acceptance: replay determinism
# ---------------------------------------------------------------------------

def test_replay_same_trace_and_seed_is_bit_identical():
    """Same trace + seed ⇒ identical event log and per-submission makespans."""
    trace = generate_trace(
        14, seed=11, rate=3.0, families=("mri", "tpu"), node_events=True,
    )
    results = []
    for _ in range(2):
        svc = SchedulingService(trace.system, ServiceConfig(seed=11))
        results.append(svc.run(trace))
    a, b = results
    assert a.event_log == b.event_log
    assert a.makespans() == b.makespans()
    assert [r.to_json() for r in a.records] == [r.to_json() for r in b.records]


def test_replay_determinism_with_jitter():
    """Jitter draws from per-submission derived seeds — still replayable."""
    trace = generate_trace(6, seed=2, families=("tpu",))
    cfg = ServiceConfig(seed=5, jitter=0.1)
    a = SchedulingService(trace.system, cfg).run(trace)
    b = SchedulingService(trace.system, cfg).run(trace)
    assert a.event_log == b.event_log
    assert a.makespans() == b.makespans()


# ---------------------------------------------------------------------------
# acceptance: the cache hot path
# ---------------------------------------------------------------------------

def test_repeat_identical_submission_zero_solver_invocations():
    subs = tuple(_sub(i, mri_w1(), t=i * 30.0) for i in range(4))
    trace = Trace(name="rep", system=continuum_system(), submissions=subs)
    svc = SchedulingService(trace.system, ServiceConfig())
    r = svc.run(trace)
    assert [rec.status for rec in r.records] == ["completed"] * 4
    assert r.solver_calls == 1  # only the first submission reached a solver
    assert [rec.cache_hit for rec in r.records] == [False, True, True, True]
    assert r.cache["hits"] == 3 and r.cache["misses"] == 1
    # all four executed identically (same model, no perturbation)
    mk = [rec.observed_makespan for rec in r.records]
    assert mk[0] == pytest.approx(mk[1]) == pytest.approx(mk[3])


def test_burst_of_identical_submissions_coalesces_in_one_window():
    """Duplicates arriving inside one admission window solve once: the first
    solves, its twins pick the result up at admission."""
    subs = tuple(_sub(i, mri_w1(), t=0.0) for i in range(5))
    trace = Trace(name="burst", system=continuum_system(), submissions=subs)
    r = SchedulingService(trace.system, ServiceConfig(batch_window=1.0)).run(trace)
    assert r.solver_calls == 1
    assert sum(rec.cache_hit for rec in r.records) == 4
    # the summary metric agrees with the per-record flags: 4 submissions
    # skipped the solver (coalesced twins count as hits, not misses)
    assert r.cache["hits"] == 4 and r.cache["misses"] == 1


# ---------------------------------------------------------------------------
# admission batching
# ---------------------------------------------------------------------------

def test_admission_batches_same_bucket_ga_submissions():
    """Distinct-content, same-shape GA submissions in one window route
    through the registry batch path as ONE group."""
    opts = {"generations": 3, "pop_size": 8, "seed": 0}
    subs = tuple(
        _sub(i, _chain(f"C{i}", [1.0 + i, 2.0, 3.0 + i, 1.0, 2.0, 1.0]),
             t=0.0, technique="ga", solver_options=opts)
        for i in range(3)
    )
    trace = Trace(name="batch", system=_two_node_system(), submissions=subs)
    r = SchedulingService(trace.system, ServiceConfig(batch_window=1.0)).run(trace)
    assert r.batched_groups == 1
    assert r.batched_submissions == 3
    assert all(rec.batched for rec in r.records)
    assert all(rec.status == "completed" for rec in r.records)
    assert r.solver_calls == 3  # three problems solved, one compiled program


def test_bad_options_in_batch_group_reject_without_killing_the_service():
    """A solver error inside a *batched* group must degrade exactly like the
    single-solve path: the group falls back to per-submission solves and only
    the culprits are rejected — the service run itself survives."""
    bad = {"generations": 2, "pop_size": 0, "seed": 0}  # zero-size population
    subs = (
        _sub(0, _chain("A", [1.0, 2.0]), t=0.0, technique="ga", solver_options=bad),
        _sub(1, _chain("B", [2.0, 3.0]), t=0.0, technique="ga", solver_options=bad),
        _sub(2, _chain("C", [1.0, 1.0]), t=0.0, technique="heft"),
    )
    trace = Trace(name="badbatch", system=_two_node_system(), submissions=subs)
    r = SchedulingService(trace.system, ServiceConfig(batch_window=1.0)).run(trace)
    assert [rec.status for rec in r.records] == ["rejected", "rejected", "completed"]


def test_record_json_is_strict_even_for_rejected_submissions():
    """Rejected records keep NaN timestamps internally but must serialize to
    strict JSON (null, not bare NaN tokens)."""
    wf = Workflow("needs-f2", (Task("T0", features=frozenset({"F2"})),))
    trace = Trace(name="nan", system=_single_node_system(),
                  submissions=(_sub(0, wf, t=0.0),))
    r = SchedulingService(trace.system, ServiceConfig()).run(trace)
    obj = r.records[0].to_json()
    assert obj["status"] == "rejected"
    assert obj["finished"] is None and obj["observed_makespan"] is None
    json.dumps([rec.to_json() for rec in r.records], allow_nan=False)  # no raise


def test_typoed_solver_option_rejects_one_tenant_not_the_service():
    """Misspelled solver_options raise TypeError inside the technique —
    that must reject the one submission, not abort the multi-tenant run."""
    subs = (
        _sub(0, _chain("A", [1.0, 2.0]), t=0.0, technique="ga",
             solver_options={"popsize": 8}),  # typo for pop_size
        _sub(1, _chain("B", [2.0, 1.0]), t=0.0, technique="heft"),
    )
    trace = Trace(name="typo", system=_two_node_system(), submissions=subs)
    r = SchedulingService(trace.system, ServiceConfig(batch_window=0.5)).run(trace)
    assert [rec.status for rec in r.records] == ["rejected", "completed"]


def test_declined_batch_is_not_reported_as_batched():
    """When the technique's batch fn declines at runtime (per-instance-only
    backend option), submissions fall back to singles and nothing claims a
    batch happened."""
    opts = {"generations": 2, "pop_size": 8, "seed": 0, "backend": "pallas"}
    subs = tuple(
        _sub(i, _chain(f"D{i}", [1.0 + i, 2.0]), t=0.0, technique="ga",
             solver_options=opts)
        for i in range(2)
    )
    trace = Trace(name="decline", system=_two_node_system(), submissions=subs)
    r = SchedulingService(trace.system, ServiceConfig(batch_window=0.5)).run(trace)
    assert [rec.status for rec in r.records] == ["completed", "completed"]
    assert r.batched_groups == 0 and r.batched_submissions == 0
    assert not any(rec.batched for rec in r.records)
    assert r.solver_calls == 2


def test_service_config_rejects_degenerate_knobs():
    with pytest.raises(ValueError, match="max_batch"):
        ServiceConfig(max_batch=0)  # would spin the admit loop forever
    with pytest.raises(ValueError, match="batch_window"):
        ServiceConfig(batch_window=-1.0)
    with pytest.raises(ValueError, match="cache_capacity"):
        ServiceConfig(cache_capacity=0)


def test_unknown_node_in_trace_event_fails_fast():
    trace = Trace(
        name="badnode",
        system=_single_node_system(),
        submissions=(_sub(0, _chain("C", [1.0]), t=1.0),),
        events=(NodeEvent(time=0.0, kind="node-failure", node="N9"),),
    )
    with pytest.raises(ValueError, match="unknown node 'N9'"):
        SchedulingService(trace.system, ServiceConfig()).run(trace)


def test_duplicate_submission_ids_fail_fast():
    subs = (_sub(0, _chain("A", [1.0]), t=0.0), _sub(0, _chain("B", [2.0]), t=1.0))
    trace = Trace(name="dupid", system=_single_node_system(), submissions=subs)
    with pytest.raises(ValueError, match="duplicate submission id"):
        SchedulingService(trace.system, ServiceConfig()).run(trace)


def test_generated_node_events_target_the_embedded_system():
    """node_events=True must emit events consumable by serve_trace even for
    a custom system (targets drawn from the embedded nodes)."""
    system = _two_node_system()
    trace = generate_trace(
        6, seed=1, families=("random",), system=system, node_events=True,
    )
    assert {e.node for e in trace.events} <= {"N1", "N2"}
    r = SchedulingService(trace.system, ServiceConfig()).run(trace)  # no raise
    assert len(r.records) == 6


def test_coalesced_twin_of_rejected_solve_is_not_a_cache_hit():
    """Identical infeasible submissions in one window: the representative's
    invalid solve is never cached, so its twin must count as a miss (and be
    rejected), keeping hit_rate consistent with solver work skipped."""
    wf = Workflow("needs-f2", (Task("T0", features=frozenset({"F2"})),))
    subs = (_sub(0, wf, t=0.0), _sub(1, wf, t=0.0))
    trace = Trace(name="twin-rej", system=_single_node_system(), submissions=subs)
    r = SchedulingService(trace.system, ServiceConfig(batch_window=1.0)).run(trace)
    assert [rec.status for rec in r.records] == ["rejected", "rejected"]
    assert not any(rec.cache_hit for rec in r.records)
    assert r.cache["hits"] == 0 and r.cache["misses"] == 2


def test_max_batch_overflow_readmits_in_order():
    subs = tuple(_sub(i, mri_w1(), t=0.0) for i in range(5))
    trace = Trace(name="overflow", system=continuum_system(), submissions=subs)
    r = SchedulingService(
        trace.system, ServiceConfig(batch_window=0.5, max_batch=2)
    ).run(trace)
    assert all(rec.status == "completed" for rec in r.records)
    admits = [e for e in r.event_log if e["kind"] == "admit"]
    assert len(admits) >= 3  # 5 submissions / max_batch 2


# ---------------------------------------------------------------------------
# monitor feedback, drift, failures
# ---------------------------------------------------------------------------

def test_drift_invalides_cache_and_model_converges():
    """After a node-drift event the next identical submission must MISS the
    cache (content key changed via the refreshed model) and its prediction
    must match observation (monitor learned the true speed)."""
    wf = _chain("C", [2.0, 3.0, 1.0])
    subs = (_sub(0, wf, t=0.0), _sub(1, wf, t=50.0))
    trace = Trace(
        name="drift",
        system=_single_node_system(),
        submissions=subs,
        events=(NodeEvent(time=0.0, kind="node-drift", node="N1", factor=0.5),),
    )
    r = SchedulingService(trace.system, ServiceConfig()).run(trace)
    r0, r1 = r.records
    # first solve predicted the unperturbed model, observed 2x slower
    assert r0.observed_makespan == pytest.approx(2.0 * r0.predicted_makespan)
    # second submission: cache miss (model changed), converged prediction
    assert not r1.cache_hit
    assert r.solver_calls == 2
    assert r1.observed_makespan == pytest.approx(r1.predicted_makespan)
    assert r1.predicted_makespan == pytest.approx(2.0 * r0.predicted_makespan)


def test_node_failure_routes_around_and_recovery_restores():
    wf = _chain("C", [2.0, 1.0])
    subs = (_sub(0, wf, t=1.0), _sub(1, wf, t=30.0))
    trace = Trace(
        name="fail",
        system=_two_node_system(),
        submissions=subs,
        events=(
            NodeEvent(time=0.0, kind="node-failure", node="N2"),
            NodeEvent(time=20.0, kind="node-recovery", node="N2"),
        ),
    )
    r = SchedulingService(trace.system, ServiceConfig()).run(trace)
    assert [rec.status for rec in r.records] == ["completed", "completed"]
    nodes_used = {
        e["id"]: set()
        for e in r.event_log if e["kind"] == "dispatch"
    }
    for e in r.event_log:
        if e["kind"] == "task-finished":
            nodes_used[e["id"]].add(e["node"])
    # while N2 was down, everything ran on N1
    assert nodes_used["s000"] == {"N1"}
    # after recovery, the 4x faster N2 is used again
    assert "N2" in nodes_used["s001"]
    # the failure also invalidated the cached solve (different feasibility)
    assert r.solver_calls == 2


def test_infeasible_submission_rejected_not_crashing():
    wf = Workflow("needs-f2", (Task("T0", features=frozenset({"F2"})),))
    subs = (_sub(0, wf, t=0.0), _sub(1, _chain("ok", [1.0, 2.0]), t=1.0))
    trace = Trace(name="rej", system=_single_node_system(), submissions=subs)
    r = SchedulingService(trace.system, ServiceConfig()).run(trace)
    assert r.records[0].status == "rejected"
    assert r.records[1].status == "completed"
    assert any(e["kind"] == "rejected" and e["id"] == "s000"
               for e in r.event_log)
    # makespans() maps rejected to None (not NaN), so replays compare equal
    r2 = SchedulingService(trace.system, ServiceConfig()).run(trace)
    assert r.makespans()["s000"] is None
    assert r.makespans() == r2.makespans()


def test_contention_delays_overlapping_tenants():
    """Two simultaneous submissions on a one-node continuum cannot overlap:
    the second waits for the first's reserved window (queueing delay)."""
    wf = _chain("C", [4.0, 4.0])
    subs = (_sub(0, wf, t=0.0), _sub(1, wf, t=0.0))
    trace = Trace(name="contend", system=_single_node_system(), submissions=subs)
    r = SchedulingService(trace.system, ServiceConfig(batch_window=0.5)).run(trace)
    r0, r1 = r.records
    assert r0.queue_delay == 0.0
    assert r1.queue_delay == pytest.approx(r0.observed_makespan)
    assert r1.turnaround > r0.turnaround


# ---------------------------------------------------------------------------
# fault tolerance: preemption, requeue/backoff, terminal failure
# ---------------------------------------------------------------------------

def test_event_loop_cancellation_skips_silently():
    loop = EventLoop()
    keep = loop.push(1.0, "keep")
    drop = loop.push(2.0, "drop")
    loop.push(3.0, "tail")
    assert loop.cancel(drop) is True
    assert loop.cancel(drop) is False  # idempotent
    assert len(loop) == 2
    kinds = [ev.kind for ev in loop.drain()]
    assert kinds == ["keep", "tail"]
    assert keep.seq not in loop._cancelled


def test_retry_backoff_doubles_then_caps():
    from repro.service import retry_backoff

    assert [retry_backoff(i, base=1.0, cap=10.0) for i in range(1, 6)] == [
        1.0, 2.0, 4.0, 8.0, 10.0,
    ]
    with pytest.raises(ValueError, match="attempt"):
        retry_backoff(0)


def test_release_drops_cancelled_occupancy_and_recover_does_not_resurrect():
    """Satellite regression: a failed node's frontier must stop reflecting
    cancelled work, keep the truncated busy time, and stay deflated across
    a recovery."""
    from repro.core.simulator import ExecutionReport, TaskLog
    from repro.service import ContinuumState

    st = ContinuumState(_single_node_system())
    rep = ExecutionReport(
        logs=[TaskLog("T0", 0, 0.0, 10.0, 10.0)],
        makespan=10.0, predicted_makespan=10.0, slowdown=1.0,
    )
    st.reserve(rep, t0=0.0, sid="s0")
    assert st.frontier["N1"] == 10.0
    st.fail("N1")
    lost, cancelled = st.release("s0", at=1.0)
    assert lost == pytest.approx(1.0) and cancelled == 1
    # only the really-elapsed second remains on the frontier...
    assert st.frontier["N1"] == pytest.approx(1.0)
    st.recover("N1")
    # ...and recovery must not resurrect the cancelled window
    assert st.frontier["N1"] == pytest.approx(1.0)
    assert st.busy_seconds["N1"] == pytest.approx(1.0)
    # releasing an unknown/already-released sid is a no-op
    assert st.release("s0", at=5.0) == (0.0, 0)


def test_midrun_failure_preempts_salvages_and_completes_after_recovery():
    """The tentpole end to end on one node: failure mid-task cancels the
    stale completion, salvages the finished prefix, requeues the remainder
    with backoff, and the submission completes after recovery."""
    wf = _chain("C", [2.0, 2.0, 2.0])  # runs [0.25,2.25][2.25,4.25][4.25,6.25]
    trace = Trace(
        name="preempt",
        system=_single_node_system(),
        submissions=(_sub(0, wf, t=0.0),),
        events=(
            NodeEvent(time=3.0, kind="node-failure", node="N1"),
            NodeEvent(time=10.0, kind="node-recovery", node="N1"),
        ),
    )
    cfg = ServiceConfig(max_retries=5, backoff_base=1.0, backoff_cap=8.0)
    r = SchedulingService(trace.system, cfg).run(trace)
    rec = r.records[0]
    assert rec.status == "completed"
    assert rec.retries >= 2  # preemption + transient infeasibility while down
    assert rec.rescheduled_tasks == 2  # T1 (mid-flight) and T2 (future)
    assert rec.lost_work_seconds == pytest.approx(0.75)  # T1 ran 2.25→3.0
    pre = [e for e in r.event_log if e["kind"] == "preempted"]
    assert len(pre) == 1
    assert pre[0]["salvaged"] == 1 and pre[0]["rescheduled"] == 2
    # the pre-computed completion for t=6.25 was cancelled: exactly one
    # completion fires, after the recovery
    comps = [e for e in r.event_log if e["kind"] == "completion"]
    assert len(comps) == 1 and comps[0]["time"] > 10.0
    assert any(e["kind"] == "requeue" for e in r.event_log)
    # stretch metrics surface in the summary
    s = r.summary()
    assert s["robustness"]["retries"] == rec.retries
    assert s["robustness"]["lost_work_seconds"] == pytest.approx(0.75)
    assert s["robustness"]["makespan_stretch"]["mean"] > 1.0
    # and the chaos path stays replayable
    r2 = SchedulingService(trace.system, cfg).run(trace)
    assert r.event_log == r2.event_log
    assert r.makespans() == r2.makespans()


def test_preemption_releases_dead_node_occupancy_for_later_tenants():
    """Satellite regression at the service level: with the preempted work
    terminally failed (max_retries=0), a later submission must see a
    frontier reflecting only the salvaged second, not the cancelled ten."""
    a = Workflow("long", (Task("T0", cores=2, work=10.0,
                               features=frozenset({"F1"})),))
    b = _chain("B", [1.0])
    trace = Trace(
        name="stale-occ",
        system=_single_node_system(),
        submissions=(_sub(0, a, t=0.0), _sub(1, b, t=5.0)),
        events=(
            NodeEvent(time=1.0, kind="node-failure", node="N1"),
            NodeEvent(time=2.0, kind="node-recovery", node="N1"),
        ),
    )
    r = SchedulingService(
        trace.system, ServiceConfig(max_retries=0)
    ).run(trace)
    ra, rb = r.records
    assert ra.status == "failed"
    assert "retry budget exhausted" in ra.reason
    assert r.makespans()["s000"] is None
    # stale occupancy would have forced rb to wait until t≈10.25
    assert rb.status == "completed"
    assert rb.queue_delay == 0.0
    assert any(e["kind"] == "failed" and e["id"] == "s000"
               for e in r.event_log)
    assert r.summary()["failed"] == 1


def test_failure_before_admission_retries_until_recovery():
    """A submission whose admission window opens during a full outage is
    transiently infeasible: it must back off and complete post-recovery
    instead of being rejected."""
    trace = Trace(
        name="down-at-admit",
        system=_single_node_system(),
        submissions=(_sub(0, _chain("C", [1.0, 1.0]), t=0.5),),
        events=(
            NodeEvent(time=0.0, kind="node-failure", node="N1"),
            NodeEvent(time=4.0, kind="node-recovery", node="N1"),
        ),
    )
    r = SchedulingService(
        trace.system, ServiceConfig(max_retries=5, backoff_base=1.0)
    ).run(trace)
    rec = r.records[0]
    assert rec.status == "completed"
    assert rec.retries > 0
    assert rec.rescheduled_tasks == 0  # never dispatched before the outage
    assert not any(e["kind"] == "rejected" for e in r.event_log)


def test_retry_budget_exhaustion_is_terminal_failed_with_reason():
    trace = Trace(
        name="budget",
        system=_single_node_system(),
        submissions=(_sub(0, _chain("C", [4.0]), t=0.0),),
        events=(NodeEvent(time=1.0, kind="node-failure", node="N1"),),
    )
    r = SchedulingService(
        trace.system, ServiceConfig(max_retries=1, backoff_base=0.5)
    ).run(trace)
    rec = r.records[0]
    assert rec.status == "failed"
    assert "retry budget exhausted (1)" in rec.reason
    assert math.isnan(rec.observed_makespan)
    assert rec.finished > 0 and rec.turnaround > 0
    json.dumps(rec.to_json(), allow_nan=False)  # still strict JSON
    fails = [e for e in r.event_log if e["kind"] == "failed"]
    assert len(fails) == 1 and fails[0]["reason"] == rec.reason


def test_drift_after_dispatch_does_not_rewrite_inflight_work():
    """Drift lands between dispatch and completion: the in-flight execution
    keeps its dispatch-time speeds; only later submissions see the change."""
    wf = _chain("C", [2.0, 2.0])
    trace = Trace(
        name="drift-mid",
        system=_single_node_system(),
        submissions=(_sub(0, wf, t=0.0), _sub(1, wf, t=30.0)),
        events=(NodeEvent(time=1.0, kind="node-drift", node="N1", factor=0.5),),
    )
    r = SchedulingService(trace.system, ServiceConfig()).run(trace)
    r0, r1 = r.records
    assert r0.status == r1.status == "completed"
    # in-flight work unaffected (model and truth agreed at dispatch time)
    assert r0.observed_makespan == pytest.approx(r0.predicted_makespan)
    # the later tenant executes at the drifted speed: twice as slow as the
    # (not yet converged) model predicts
    assert r1.observed_makespan == pytest.approx(2.0 * r1.predicted_makespan)


def test_set_drift_rejects_nonpositive_factors():
    from repro.service import ContinuumState

    st = ContinuumState(_single_node_system())
    for bad in (0.0, -1.0, float("nan")):
        with pytest.raises(ValueError, match="drift factor"):
            st.set_drift("N1", bad)
    # and the service fails fast at run() on a bad trace event
    trace = Trace(
        name="bad-drift",
        system=_single_node_system(),
        submissions=(_sub(0, _chain("C", [1.0]), t=1.0),),
        events=(NodeEvent(time=0.0, kind="node-drift", node="N1", factor=0.0),),
    )
    with pytest.raises(ValueError, match="factor > 0"):
        SchedulingService(trace.system, ServiceConfig()).run(trace)


def test_unexpected_solver_exception_rejects_with_recorded_error():
    """An arbitrary (non-ValueError/TypeError) solver crash must reject the
    one submission with a recorded reason, not abort the run."""
    from repro.core.api import REGISTRY, SolverRegistry
    from repro.core.evaluator import ObjectiveWeights

    reg = SolverRegistry()

    def boom(problem, weights=ObjectiveWeights(), **kw):
        raise RuntimeError("synthetic solver crash")

    reg.register("boom", boom)
    reg.register("heft", REGISTRY.get("heft").fn)
    subs = (
        _sub(0, _chain("A", [1.0, 2.0]), t=0.0, technique="boom"),
        _sub(1, _chain("B", [2.0, 1.0]), t=0.0, technique="heft"),
    )
    trace = Trace(name="crash", system=_two_node_system(), submissions=subs)
    svc = SchedulingService(trace.system, ServiceConfig(batch_window=0.5),
                            registry=reg)
    r = svc.run(trace)
    assert [rec.status for rec in r.records] == ["rejected", "completed"]
    assert r.records[0].reason == "RuntimeError: synthetic solver crash"


def test_fallback_chain_completes_submission_via_degraded_technique():
    from repro.core.api import REGISTRY, SolverRegistry
    from repro.core.evaluator import ObjectiveWeights

    reg = SolverRegistry()

    def boom(problem, weights=ObjectiveWeights(), **kw):
        raise RuntimeError("synthetic solver crash")

    reg.register("boom", boom)
    reg.register("heft", REGISTRY.get("heft").fn)
    trace = Trace(
        name="fallback",
        system=_two_node_system(),
        submissions=(_sub(0, _chain("A", [1.0, 2.0]), t=0.0, technique="boom"),),
    )
    svc = SchedulingService(
        trace.system, ServiceConfig(fallback=("heft",)), registry=reg
    )
    r = svc.run(trace)
    rec = r.records[0]
    assert rec.status == "completed"
    assert rec.technique_used == "heft"
    assert rec.fallbacks and rec.fallbacks[0].startswith("boom:RuntimeError")


def test_chaos_trace_zero_silently_lost_and_bit_identical_replay():
    """Acceptance: a chaos trace with mid-run failures ends every record in
    a terminal status (with a reason when not completed) and replays
    bit-identically at the fixed seed."""
    trace = generate_trace(
        20, seed=3, rate=2.0,
        chaos={"horizon": 400.0, "failure_rate": 0.02, "outage_mean": 30.0,
               "drift_rate": 0.02},
    )
    assert any(e.kind == "node-failure" for e in trace.events)
    cfg = ServiceConfig(batch_window=0.5, seed=3, max_retries=3,
                        backoff_base=0.5, backoff_cap=16.0)
    a = SchedulingService(trace.system, cfg).run(trace)
    b = SchedulingService(trace.system, cfg).run(trace)
    assert a.event_log == b.event_log
    assert a.makespans() == b.makespans()
    assert [r.to_json() for r in a.records] == [r.to_json() for r in b.records]
    for rec in a.records:
        assert rec.status in ("completed", "rejected", "failed")
        if rec.status != "completed":
            assert rec.reason or any(
                e["kind"] == "rejected" and e["id"] == rec.id
                for e in a.event_log
            )
    # summary totals account for every submission
    s = a.summary()
    assert s["completed"] + s["rejected"] + s["failed"] == len(a.records)
    json.dumps(s, allow_nan=False)  # strict JSON including new metric blocks


def test_service_config_rejects_degenerate_fault_knobs():
    with pytest.raises(ValueError, match="max_retries"):
        ServiceConfig(max_retries=-1)
    with pytest.raises(ValueError, match="backoff_base"):
        ServiceConfig(backoff_base=0.0)
    with pytest.raises(ValueError, match="backoff_cap"):
        ServiceConfig(backoff_cap=0.0)
    with pytest.raises(ValueError, match="solve_budget"):
        ServiceConfig(solve_budget=0.0)


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------

def test_trace_json_roundtrip_bit_exact(tmp_path):
    trace = generate_trace(10, seed=4, node_events=True)
    obj = trace.to_json()
    assert trace_from_json(json.loads(json.dumps(obj))).to_json() == obj
    p = trace.save(tmp_path / "trace.json")
    assert load_trace(p).to_json() == obj


def test_generated_trace_arrivals_sorted_and_families_valid():
    trace = generate_trace(50, seed=9)
    times = [s.time for s in trace.submissions]
    assert times == sorted(times)
    assert {s.family for s in trace.submissions} <= {"mri", "stgs", "random", "tpu"}
    assert len({s.id for s in trace.submissions}) == 50


def test_service_summary_is_json_serializable():
    trace = generate_trace(5, seed=1, families=("mri",))
    r = SchedulingService(trace.system, ServiceConfig()).run(trace)
    obj = json.loads(json.dumps(r.summary()))
    assert obj["submissions"] == 5
    assert obj["completed"] + obj["rejected"] == 5
    assert 0.0 <= obj["cache"]["hit_rate"] <= 1.0


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _repro_env():
    return {
        "PYTHONPATH": str(Path(__file__).resolve().parents[1] / "src"),
        "PATH": "/usr/bin:/bin",
        "JAX_PLATFORMS": "cpu",
    }


def test_cli_trace_and_serve(tmp_path):
    trace_path = tmp_path / "trace.json"
    out_path = tmp_path / "result.json"
    gen = subprocess.run(
        [sys.executable, "-m", "repro", "trace", str(trace_path),
         "-n", "6", "--seed", "3", "--families", "mri,tpu"],
        capture_output=True, text=True, env=_repro_env(),
    )
    assert gen.returncode == 0, gen.stderr
    assert trace_path.exists()
    serve = subprocess.run(
        [sys.executable, "-m", "repro", "serve", str(trace_path),
         "--jitter", "0.05", "--seed", "7", "--out", str(out_path)],
        capture_output=True, text=True, env=_repro_env(),
    )
    assert serve.returncode == 0, serve.stderr
    summary = json.loads(serve.stdout)
    assert summary["submissions"] == 6
    assert summary["completed"] == 6
    assert json.loads(out_path.read_text()) == summary
