"""repro.cycling: cycle unrolling (determinism + acyclicity), JSON round
trips, hard constraints through all three solver families (MILP rows, HEFT
feasibility filtering, GA penalty — bit-identical across engine backends in
f32), the service's cycling stream path (dependency gating, cycle spawning,
converging predicates, warm solve-cache re-solves, pinned replay
fingerprint), and the cycling campaign's constraint-satisfaction report."""

import json

import numpy as np
import pytest

from repro.core import heuristics
from repro.core.api import Scenario, route_problem, scenario_from_json
from repro.core.evaluator import ObjectiveWeights
from repro.core.milp import solve_milp
from repro.core.system_model import Node, make_system, synthetic_system
from repro.core.workload_model import (
    Constraints,
    Workload,
    build_problem,
    canonical_hash,
    constraints_from_json,
    mri_w1,
    mri_workload,
    problem_fingerprint,
    random_layered_workflow,
    topological_order,
    workload_to_json,
)
from repro.cycling import (
    ConvergeSpec,
    CycleSpec,
    cross_edges,
    cycle_spec_from_json,
    resolve_cycles,
    roots_and_sinks,
    task_cycle_name,
    unroll,
    unroll_constraints,
    unroll_workload,
)
from repro.engine import ENGINES
from repro.service.service import SchedulingService, ServiceConfig
from repro.service.traces import Submission, Trace, continuum_system, generate_trace


def _two_node_system():
    """Speed-1.0 nodes: observed durations equal modeled ones exactly."""
    nodes = [
        Node(f"N{i}", {"cores": 64, "storage": 500}, frozenset({"F1", "F2"}),
             {"processing_speed": 1.0, "data_transfer_rate": 100.0})
        for i in (1, 2)
    ]
    return make_system(nodes)


# ---------------------------------------------------------------------------
# CycleSpec / ConvergeSpec
# ---------------------------------------------------------------------------

def test_cycle_spec_json_round_trip():
    spec = CycleSpec(cycles=3, period=5.0, cross=(("T2", "T0"), ("*", "*")),
                     cycle_deadline=20.0)
    rt = cycle_spec_from_json(json.loads(json.dumps(spec.to_json())))
    assert rt == spec
    conv = CycleSpec(
        converge=ConvergeSpec(prob=0.6, min_cycles=2, max_cycles=5, seed=7),
        period=3.0,
    )
    assert cycle_spec_from_json(json.loads(json.dumps(conv.to_json()))) == conv
    assert cycle_spec_from_json(None) is None


def test_cycle_spec_validation():
    with pytest.raises(ValueError):
        CycleSpec()  # neither cycles nor converge
    with pytest.raises(ValueError):
        CycleSpec(cycles=2, converge=ConvergeSpec())  # both
    with pytest.raises(ValueError):
        CycleSpec(cycles=0)
    with pytest.raises(ValueError):
        CycleSpec(cycles=1, cycle_deadline=0.0)
    with pytest.raises(ValueError):
        ConvergeSpec(prob=1.5)
    with pytest.raises(ValueError):
        ConvergeSpec(min_cycles=5, max_cycles=3)
    with pytest.raises(ValueError, match="unknown"):
        cycle_spec_from_json({"cycles": 2, "perod": 1.0})


def test_converge_predicate_seeded_and_bounded():
    conv = ConvergeSpec(prob=0.5, min_cycles=2, max_cycles=6, seed=3)
    # deterministic: same (name, cycle) always answers the same
    for cycle in range(6):
        assert conv.converged("S1", cycle) == conv.converged("S1", cycle)
    # never below min_cycles, always by max_cycles
    assert not conv.converged("S1", 0)
    assert conv.converged("S1", conv.max_cycles - 1)
    n1, n2 = conv.revealed_cycles("S1"), conv.revealed_cycles("S2")
    assert conv.min_cycles <= n1 <= conv.max_cycles
    assert conv.min_cycles <= n2 <= conv.max_cycles
    # a different seed reshuffles the reveal (for at least some stream)
    other = ConvergeSpec(prob=0.5, min_cycles=2, max_cycles=6, seed=99)
    assert any(
        other.revealed_cycles(f"S{i}") != conv.revealed_cycles(f"S{i}")
        for i in range(8)
    )


# ---------------------------------------------------------------------------
# Unrolling
# ---------------------------------------------------------------------------

def test_unroll_names_deps_and_cross_edges():
    wf = mri_w1()
    spec = CycleSpec(cycles=2, period=4.0)
    u = unroll(wf, spec)
    assert len(u.tasks) == 2 * len(wf.tasks)
    names = {t.name for t in u.tasks}
    for t in wf.tasks:
        assert task_cycle_name(t.name, 0) in names
        assert task_cycle_name(t.name, 1) in names
    roots, sinks = roots_and_sinks(wf)
    by_name = {t.name: t for t in u.tasks}
    # "*"→"*" cross edges: every cycle-1 root depends on every cycle-0 sink
    for r in roots:
        deps = set(by_name[task_cycle_name(r, 1)].deps)
        for s in sinks:
            assert task_cycle_name(s, 0) in deps


def test_cross_edges_explicit_and_invalid():
    wf = mri_w1()
    edges = cross_edges(wf, CycleSpec(cycles=2, cross=(("T2", "T1"),)))
    assert ("T2", "T1") in edges
    with pytest.raises(ValueError, match="Nope"):
        cross_edges(wf, CycleSpec(cycles=2, cross=(("Nope", "T1"),)))


def test_resolve_cycles_fixed_vs_converging():
    assert resolve_cycles(CycleSpec(cycles=4)) == 4
    conv = CycleSpec(converge=ConvergeSpec(min_cycles=2, max_cycles=5, seed=0))
    assert resolve_cycles(conv) == conv.max_cycles()
    assert resolve_cycles(conv, cycles=3) == 3


def test_unroll_constraints_per_cycle_deadlines():
    wl = Workload((mri_w1(),))
    spec = CycleSpec(cycles=2, period=4.0, cycle_deadline=10.0)
    cons = unroll_constraints(wl, spec, base=Constraints(budget={"W1": 99.0}))
    wf = wl.workflows[0]
    for k, task in ((0, wf.tasks[0].name), (1, wf.tasks[0].name)):
        key = f"W1/{task_cycle_name(task, k)}"
        assert cons.deadline[key] == (k + 1) * 10.0
    assert cons.budget == {"W1": 99.0}
    # no cycle_deadline → base constraints pass through untouched
    base = Constraints(deadline={"W1": 5.0})
    assert unroll_constraints(wl, CycleSpec(cycles=2), base=base) is base


def test_unroll_determinism_and_acyclicity_fuzzed():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(
        size=st.integers(min_value=2, max_value=12),
        seed=st.integers(min_value=0, max_value=2**16),
        cycles=st.integers(min_value=1, max_value=4),
    )
    def check(size, seed, cycles):
        wf = random_layered_workflow(
            size, name="W", seed=seed, max_cores=4, feature_pool=("F1",)
        )
        spec = CycleSpec(cycles=cycles, period=1.0)
        a, b = unroll(wf, spec), unroll(wf, spec)
        assert canonical_hash(workload_to_json(Workload((a,)))) == (
            canonical_hash(workload_to_json(Workload((b,))))
        )
        assert len(a.tasks) == cycles * size
        assert topological_order(a.tasks) is not None  # acyclic

    check()


# ---------------------------------------------------------------------------
# Constraints → ScheduleProblem → solver families
# ---------------------------------------------------------------------------

def _constrained_problem(deadline=11.0):
    cons = Constraints(
        deadline={"W1": deadline},
        budget={"W2": 500.0},
        cost_rate={"N2": 2.0},
    )
    return build_problem(_two_node_system(), mri_workload(), cons)


def test_build_problem_constraint_arrays_and_fingerprint():
    p0 = build_problem(_two_node_system(), mri_workload())
    assert not p0.has_constraints
    p = _constrained_problem()
    assert p.has_constraints
    w1 = [j for j in range(p.num_tasks) if p.workflow_of[j] == 0]
    assert all(p.deadline[j] == 11.0 for j in w1)
    # padding convention: unconstrained tasks carry +inf deadline
    w2 = [j for j in range(p.num_tasks) if p.workflow_of[j] == 1]
    assert all(np.isinf(p.deadline[j]) for j in w2)
    # constraints flow into the fingerprint; absence keeps it stable
    assert problem_fingerprint(p) != problem_fingerprint(p0)
    assert problem_fingerprint(p0) == problem_fingerprint(
        build_problem(_two_node_system(), mri_workload())
    )


def test_constraints_json_round_trip_and_unknown_keys():
    cons = Constraints(deadline={"W1": 11.0}, budget={"W2": 2.0},
                       cost_rate={"N2": 2.0}, placement={"W1": ("F1",)})
    rt = constraints_from_json(json.loads(json.dumps(cons.to_json())))
    assert rt == cons
    assert constraints_from_json(None) is None
    with pytest.raises(ValueError, match="unknown"):
        constraints_from_json({"deadlien": {"W1": 1.0}})


def test_milp_respects_and_proves_deadlines():
    loose = solve_milp(_constrained_problem(deadline=11.0))
    assert loose.status == "optimal" and loose.violations == 0
    assert loose.makespan <= 11.0 + 1e-3
    # 0.5 is below any task's duration — the LP must be infeasible
    tight = solve_milp(_constrained_problem(deadline=0.5))
    assert "failed" in tight.status


def test_heuristics_filter_constrained_candidates():
    for solver in (heuristics.heft, heuristics.olb):
        sched = solver(_constrained_problem(deadline=11.0))
        assert sched.violations == 0
        # impossible deadline: greedy fallback still produces a schedule
        # (flagged violated) rather than dying — MILP proves infeasibility
        sched = solver(_constrained_problem(deadline=0.5))
        assert sched.violations > 0


def test_ga_penalty_fitness_bit_identical_across_backends_f32():
    # 10.0 = W1's serial chain with zero transfers: any candidate that
    # splits W1 across nodes pays a transfer and violates, single-node
    # placements meet it — so the 64 random candidates mix both regimes
    p = _constrained_problem(deadline=10.0)
    w = ObjectiveWeights()
    rng = np.random.default_rng(0)
    pop = rng.integers(0, p.num_nodes, size=(64, p.num_tasks), dtype=np.int32)
    results = {}
    for name in ("oracle", "jax", "pallas"):
        obj, mk = ENGINES.get(name).population_fitness(p, w)(pop)
        # the engines' comparison convention (see test_engine.py): oracle
        # widens to f64, device backends stay f32 — compare in f32
        results[name] = (
            np.asarray(obj).astype(np.float32),
            np.asarray(mk).astype(np.float32),
        )
    for name in ("jax", "pallas"):
        np.testing.assert_array_equal(results[name][0], results["oracle"][0])
        np.testing.assert_array_equal(results[name][1], results["oracle"][1])
    # the penalty actually fired: a deadline this tight on 64 random
    # assignments must push some candidates above the violation floor
    assert (np.asarray(results["jax"][0]) >= 1e9).any()


def test_ga_solver_honors_constraints_at_loose_deadline():
    rep = route_problem(
        _constrained_problem(deadline=20.0),
        technique="ga",
        options={"ga": {"seed": 0, "pop_size": 32, "generations": 12}},
    )
    assert rep.schedule.violations == 0
    assert rep.schedule.makespan <= 20.0


def test_scenario_cycling_and_constraints_sections_round_trip():
    text = json.dumps({
        "scenario": {"name": "s", "technique": "heft"},
        "nodes": {
            "N1": {"resources": {"cores": 64, "storage": 100},
                   "features": ["F1", "F2"],
                   "quality": {"processing_speed": 1.0,
                               "data_transfer_rate": 100.0}},
        },
        "W1": {"tasks": {
            "T0": {"duration": 2, "cores": 1, "features": ["F1"]},
            "T1": {"duration": 3, "cores": 1, "features": ["F1"],
                   "deps": ["T0"]},
        }},
        "constraints": {"deadline": {"W1": 30.0}},
        "cycling": {"cycles": 2, "period": 4.0},
    })
    sc = scenario_from_json(text)
    assert sc.cycling == CycleSpec(cycles=2, period=4.0)
    rt = scenario_from_json(json.dumps(sc.to_json()))
    assert rt.cycling == sc.cycling and rt.constraints == sc.constraints
    workload, cons = sc.expanded()
    assert len(workload.workflows[0].tasks) == 4  # 2 tasks × 2 cycles
    assert cons.deadline == {"W1": 30.0}
    # a scenario without the sections emits neither key (byte stability)
    plain = Scenario(name="p", system=sc.system, workload=sc.workload)
    assert "cycling" not in plain.to_json()
    assert "constraints" not in plain.to_json()


# ---------------------------------------------------------------------------
# Service: gating, spawning, converging, warm re-solves
# ---------------------------------------------------------------------------

def _stream(sid, wf, t, cycling=None, after=(), technique="heft"):
    return Submission(id=sid, tenant="t0", time=float(t), family="mri",
                      workflow=wf, technique=technique, cycling=cycling,
                      after=tuple(after))


def test_service_spawns_fixed_cycles_with_warm_cache():
    spec = CycleSpec(cycles=3, period=5.0)
    trace = Trace(name="fix", system=continuum_system(),
                  submissions=(_stream("s0", mri_w1(), 0.0, cycling=spec),))
    res = SchedulingService(trace.system, ServiceConfig(seed=0)).run(trace)
    ids = [r.id for r in res.records]
    assert ids == ["s0", "s0@c1", "s0@c2"]
    assert [r.cycle for r in res.records] == [0, 1, 2]
    assert all(r.status == "completed" for r in res.records)
    assert res.cycling["spawned_cycles"] == 2
    # content-identical per-cycle workflows: every re-solve is a cache hit
    assert res.solver_calls == 1
    assert res.cache["hits"] == 2
    kinds = [e["kind"] for e in res.event_log]
    assert kinds.count("cycle-spawned") == 2
    assert kinds.count("converged") == 1
    # cycle k+1 never dispatches before cycle k completes
    completions = {e["id"]: e["time"] for e in res.event_log
                   if e["kind"] == "completion"}
    dispatches = {e["id"]: e["time"] for e in res.event_log
                  if e["kind"] == "dispatch"}
    assert dispatches["s0@c1"] >= completions["s0"]
    assert dispatches["s0@c2"] >= completions["s0@c1"]


def test_service_converging_stream_ends_by_predicate():
    conv = CycleSpec(
        converge=ConvergeSpec(prob=0.5, min_cycles=2, max_cycles=6, seed=3),
        period=2.0,
    )
    trace = Trace(name="cvg", system=continuum_system(),
                  submissions=(_stream("cvg", mri_w1(), 0.0, cycling=conv),))
    res = SchedulingService(trace.system, ServiceConfig(seed=0)).run(trace)
    revealed = conv.converge.revealed_cycles("cvg")
    assert len(res.records) == revealed
    assert res.cycling["converged_streams"] == 1
    assert res.cycling["spawned_cycles"] == revealed - 1


def test_service_cycle_deadline_misses_counted():
    # W1 runs 10.02 virtual seconds per cycle on the continuum system
    spec = CycleSpec(cycles=2, period=0.0, cycle_deadline=8.0)
    trace = Trace(name="dl", system=continuum_system(),
                  submissions=(_stream("d", mri_w1(), 0.0, cycling=spec),))
    res = SchedulingService(trace.system, ServiceConfig(seed=0)).run(trace)
    assert all(r.deadline_miss for r in res.records)
    assert res.summary()["deadline_misses"] == 2
    assert any(e["kind"] == "deadline-miss" for e in res.event_log)


def test_service_after_gates_and_cascades():
    wf = mri_w1()
    subs = (
        _stream("a", wf, 0.0),
        _stream("b", wf, 0.5, after=("a",)),  # gated until a completes
    )
    trace = Trace(name="gate", system=continuum_system(), submissions=subs)
    res = SchedulingService(trace.system, ServiceConfig(seed=0)).run(trace)
    recs = {r.id: r for r in res.records}
    assert recs["a"].status == "completed"
    assert recs["b"].status == "completed"
    assert res.cycling["gated_submissions"] == 1
    assert recs["b"].dispatched >= recs["a"].finished
    # a failed dependency cascades: impossible feature → a rejected → b too
    import dataclasses

    base = mri_w1()
    bad = dataclasses.replace(
        base,
        tasks=tuple(
            dataclasses.replace(t, features=frozenset({"NO_SUCH_FEATURE"}))
            for t in base.tasks
        ),
    )
    subs = (_stream("a", bad, 0.0), _stream("b", wf, 0.5, after=("a",)))
    trace = Trace(name="cascade", system=continuum_system(), submissions=subs)
    res = SchedulingService(trace.system, ServiceConfig(seed=0)).run(trace)
    recs = {r.id: r for r in res.records}
    assert recs["a"].status == "rejected"
    assert recs["b"].status == "rejected"
    assert "dependency-failed" in recs["b"].reason


def test_service_unknown_after_reference_rejected():
    trace = Trace(
        name="bad", system=continuum_system(),
        submissions=(_stream("b", mri_w1(), 0.0, after=("ghost",)),),
    )
    with pytest.raises(ValueError, match="ghost"):
        SchedulingService(trace.system, ServiceConfig()).run(trace)


def test_converging_replay_fingerprint_pinned():
    """The converging-stream fixture replays bit-identically — pinned, so a
    behavior change in the event loop, solver path, or cache shows up as a
    fingerprint diff here (regenerate via
    ``repro.campaigns.builtin._converging_service_section``)."""
    from repro.campaigns.builtin import _converging_service_section

    section = _converging_service_section()
    assert section["replay_bit_identical"]
    assert section["streams"]["converged_streams"] == 2
    assert section["streams"]["spawned_cycles"] > 0
    # warm re-solves: every spawned cycle + the duplicate W1 stream hit
    assert section["solve_cache"]["hits"] >= section["streams"]["spawned_cycles"]
    assert section["deadline_misses"] > 0  # the cd=8 stream misses
    assert section["replay_fingerprint"] == (
        "820bbd5dcab25e9a644031ba39cdcd0ed4e0e34b33bf20c0e3c0d8844d2d15cb"
    )


def test_cycling_streams_replay_with_chaos():
    """Cycling + chaos compose: spawned cycles ride through failure storms
    deterministically (two runs, identical logs and records)."""
    trace = generate_trace(
        10, seed=5, rate=2.0, families=("mri",),
        chaos={"horizon": 120.0, "failure_rate": 0.01, "drift_rate": 0.02},
        cycling={"fraction": 0.4, "cycles": 2, "period": 3.0},
    )
    assert sum(1 for s in trace.submissions if s.cycling is not None) > 0
    cfg = ServiceConfig(seed=5, max_retries=3, fallback=("heft",))
    a = SchedulingService(trace.system, cfg).run(trace)
    b = SchedulingService(trace.system, cfg).run(trace)
    assert a.event_log == b.event_log
    assert [r.to_json() for r in a.records] == [r.to_json() for r in b.records]


# ---------------------------------------------------------------------------
# Trace JSON round trip (cycling + chaos + topology survive serialization)
# ---------------------------------------------------------------------------

def test_generate_trace_options_survive_json_round_trip():
    from repro.service.traces import trace_from_json

    trace = generate_trace(
        8, seed=9, rate=2.0, families=("mri", "random"),
        topology="tiny",  # "tpu" needs F9 nodes, which tiered topologies lack
        chaos={"horizon": 100.0, "failure_rate": 0.01},
        cycling={"fraction": 0.5, "cycles": 2, "period": 4.0,
                 "cycle_deadline": 50.0},
    )
    rt = trace_from_json(json.loads(json.dumps(trace.to_json())))
    assert rt.to_json() == trace.to_json()  # bit-identical re-serialization
    # the typed objects round-trip too, not just the JSON text
    assert [s.cycling for s in rt.submissions] == [
        s.cycling for s in trace.submissions
    ]
    assert rt.events == trace.events
    assert rt.meta == trace.meta
    # and the round-tripped trace replays identically to the original
    a = SchedulingService(trace.system, ServiceConfig(seed=9)).run(trace)
    b = SchedulingService(rt.system, ServiceConfig(seed=9)).run(rt)
    assert a.event_log == b.event_log


# ---------------------------------------------------------------------------
# Campaign layer: cycling cells, satisfaction report, deviation statuses
# ---------------------------------------------------------------------------

def test_cycling_campaign_cells_unroll_and_report():
    from repro.campaigns import run_campaign
    from repro.campaigns.builtin import cycling_campaign

    rs = run_campaign(cycling_campaign(techniques=("heft",)))
    rows = rs.rows()
    assert len(rows) == 4  # tightness sweep × heft
    by_tight = {r["tightness"]: r for r in rows}
    assert by_tight["none"]["constrained"] is False
    assert by_tight["none"]["satisfied"] is None
    assert by_tight["loose"]["constrained"] is True
    assert by_tight["loose"]["satisfied"] is True
    assert by_tight["tight"]["satisfied"] is False
    rep = rs.constraint_report(by=("technique",))
    r = rep.rows()[0]
    assert r["constrained_cells"] == 3 and r["satisfied_cells"] == 2
    assert r["satisfaction_rate"] == pytest.approx(2 / 3)
    assert r["makespan_mean"] is not None


def test_deviation_vs_reports_infeasible_vs_skipped():
    from repro.campaigns import ResultSet

    rows = [
        # group size=5: clean baseline
        {"technique": "milp", "size": 5, "makespan": 10.0, "solve_status": "optimal"},
        {"technique": "heft", "size": 5, "makespan": 11.0},
        # group size=8: the exact solve ran and proved infeasibility
        {"technique": "milp", "size": 8, "makespan": None,
         "solve_status": "failed(2)"},
        {"technique": "heft", "size": 8, "makespan": 12.0},
        # group size=50: MILP never ran (skip rule)
        {"technique": "heft", "size": 50, "makespan": 99.0},
    ]
    rs = ResultSet.from_rows(rows, meta={"coords": ["technique", "size"]})
    dev = rs.deviation_vs("milp")
    by = {(r["technique"], r["size"]): r for r in dev}
    assert by[("heft", 5)]["baseline_status"] == "ok"
    assert by[("heft", 5)]["gap_pct"] == pytest.approx(10.0)
    assert by[("heft", 8)]["baseline_status"] == "infeasible"
    assert by[("heft", 8)]["gap"] is None
    assert by[("heft", 50)]["baseline_status"] == "skipped"
    assert by[("heft", 50)]["gap_pct"] is None
    # a failed exact row's own fallback makespan must not pose as a baseline
    rows[2]["makespan"] = 77.0
    dev2 = ResultSet.from_rows(
        rows, meta={"coords": ["technique", "size"]}
    ).deviation_vs("milp")
    r8 = {(r["technique"], r["size"]): r for r in dev2}[("heft", 8)]
    assert r8["baseline_status"] == "infeasible" and r8["makespan_exact"] is None
