"""Optimizer math, microbatch-accumulation equivalence, data pipeline
determinism, and an end-to-end loss-decreases training run."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLMStream
from repro.models.registry import get_model
from repro.optim import adamw
from repro.train.train_step import make_train_step


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def _numpy_adamw(params, grads, m, v, step, cfg):
    out_p, out_m, out_v = {}, {}, {}
    gnorm = np.sqrt(sum((g.astype(np.float64) ** 2).sum() for g in grads.values()))
    scale = min(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = cfg.lr * min(step / max(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "cosine":
        frac = np.clip((step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        lr *= 0.5 * (1 + np.cos(np.pi * frac))
    for k in params:
        g = grads[k] * scale
        m2 = cfg.beta1 * m[k] + (1 - cfg.beta1) * g
        v2 = cfg.beta2 * v[k] + (1 - cfg.beta2) * g * g
        mh = m2 / (1 - cfg.beta1**step)
        vh = v2 / (1 - cfg.beta2**step)
        out_p[k] = params[k] - lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * params[k])
        out_m[k], out_v[k] = m2, v2
    return out_p, out_m, out_v


def test_adamw_matches_numpy_reference():
    cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=10, schedule="cosine")
    rng = np.random.default_rng(0)
    params = {"a": rng.standard_normal((4, 3)).astype(np.float32),
              "b": rng.standard_normal((5,)).astype(np.float32)}
    jparams = jax.tree.map(jnp.asarray, params)
    state = adamw.init(cfg, jparams)
    np_m = {k: np.zeros_like(v) for k, v in params.items()}
    np_v = {k: np.zeros_like(v) for k, v in params.items()}
    np_p = {k: v.copy() for k, v in params.items()}
    for step in range(1, 4):
        grads = {k: rng.standard_normal(v.shape).astype(np.float32) for k, v in params.items()}
        jparams, state, _ = adamw.update(cfg, jax.tree.map(jnp.asarray, grads), state, jparams)
        np_p, np_m, np_v = _numpy_adamw(np_p, grads, np_m, np_v, step, cfg)
        for k in params:
            np.testing.assert_allclose(np.asarray(jparams[k]), np_p[k], rtol=2e-5, atol=2e-6)


def test_lr_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="cosine")
    lrs = [float(adamw.lr_at(cfg, jnp.asarray(s))) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.0, abs=1e-6)


def test_grad_clipping_applied():
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0, weight_decay=0.0,
                            schedule="constant")
    params = {"a": jnp.zeros((4,))}
    state = adamw.init(cfg, params)
    huge = {"a": jnp.full((4,), 1e6)}
    _, _, metrics = adamw.update(cfg, huge, state, params)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-5)


# ---------------------------------------------------------------------------
# microbatch accumulation == full batch
# ---------------------------------------------------------------------------

def test_microbatch_equals_full_batch():
    api = get_model("qwen2.5-3b")
    cfg = dataclasses.replace(api.reduced, dtype="float32")
    params = api.init(jax.random.PRNGKey(0), cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant")
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)}

    s1 = jax.jit(make_train_step(api, cfg, opt_cfg, microbatches=1))
    s2 = jax.jit(make_train_step(api, cfg, opt_cfg, microbatches=2))
    p1, o1, m1 = s1(params, adamw.init(opt_cfg, params), batch)
    p2, o2, m2 = s2(params, adamw.init(opt_cfg, params), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_stream_deterministic_and_resumable():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=4, seed=7)
    s1 = SyntheticLMStream(cfg)
    batches = [s1.next_batch()["tokens"] for _ in range(5)]
    # resume from step 3
    s2 = SyntheticLMStream(cfg)
    s2.restore({"step": 3, "seed": 7})
    np.testing.assert_array_equal(s2.next_batch()["tokens"], batches[3])
    np.testing.assert_array_equal(s2.next_batch()["tokens"], batches[4])


def test_stream_host_shards_disjoint():
    kw = dict(vocab=128, seq_len=16, global_batch=8, seed=1, num_hosts=2)
    a = SyntheticLMStream(DataConfig(host_index=0, **kw)).next_batch()["tokens"]
    b = SyntheticLMStream(DataConfig(host_index=1, **kw)).next_batch()["tokens"]
    assert a.shape == (4, 16)
    assert not np.array_equal(a, b)


def test_stream_tokens_in_vocab():
    cfg = DataConfig(vocab=50, seq_len=64, global_batch=2, seed=2)
    toks = SyntheticLMStream(cfg).next_batch()["tokens"]
    assert toks.min() >= 0 and toks.max() < 50


def test_prefetcher_preserves_order():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2, seed=3)
    direct = SyntheticLMStream(cfg)
    expected = [direct.next_batch()["tokens"] for _ in range(4)]
    pf = Prefetcher(SyntheticLMStream(cfg), depth=2)
    try:
        for e in expected:
            np.testing.assert_array_equal(pf.next_batch()["tokens"], e)
    finally:
        pf.close()


# ---------------------------------------------------------------------------
# end-to-end: loss decreases on the learnable synthetic mixture
# ---------------------------------------------------------------------------

def test_training_reduces_loss():
    api = get_model("qwen2.5-3b")
    cfg = dataclasses.replace(api.reduced, dtype="float32", vocab=64)
    params = api.init(jax.random.PRNGKey(0), cfg)
    opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=80, schedule="cosine")
    opt_state = adamw.init(opt_cfg, params)
    step = jax.jit(make_train_step(api, cfg, opt_cfg, remat=False))
    stream = SyntheticLMStream(
        DataConfig(vocab=64, seq_len=64, global_batch=8, seed=0, mixture_components=2)
    )
    losses = []
    for _ in range(80):
        batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 1.0, (first, last)  # bigram mixture is learnable
