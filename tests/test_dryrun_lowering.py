"""CI-scale dry-run lowering checks: build_cell must lower (not compile —
too slow for CI) on a small mesh in a subprocess, proving the sharding
rules stay coherent independent of the 512-device production sweep."""

from tests.test_distributed import run_with_devices


def test_build_cell_lowers_small_mesh():
    run_with_devices("""
    import jax
    from repro.launch.mesh import make_mesh
    from repro.launch import dryrun

    mesh = make_mesh((2, 4), ("data", "model"))
    for arch, shape in [("qwen2.5-3b", "decode_32k"),
                        ("mamba2-780m", "train_4k"),
                        ("qwen3-moe-30b-a3b", "decode_32k")]:
        fn, specs = dryrun.build_cell(arch, shape, mesh, dryrun.POLICIES["baseline"])
        lowered = fn.lower(*specs)   # lowering exercises every sharding rule
        assert "stablehlo" in lowered.as_text()[:4000].lower() or lowered is not None
        print(arch, shape, "lowered OK")
    """)


def test_policy_presets_lower():
    run_with_devices("""
    import jax
    from repro.launch.mesh import make_mesh
    from repro.launch import dryrun
    from repro.distributed import hints
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_mesh((2, 4), ("data", "model"))
    for pol in ("serve-tp", "serve-tp2"):
        fn, specs = dryrun.build_cell("qwen2.5-3b", "decode_32k", mesh,
                                      dryrun.POLICIES[pol])
        fn.lower(*specs)
        print(pol, "lowered OK")
    # sequence-parallel hint path
    with hints.activation_pspec(NamedSharding(mesh, P("data", "model", None))):
        fn, specs = dryrun.build_cell("qwen2.5-3b", "train_4k", mesh,
                                      dryrun.POLICIES["seqpar"])
        fn.lower(*specs)
    print("seqpar lowered OK")
    """)
