"""Generator determinism: every seeded generator (random layered workflows,
synthetic workloads, arrival traces) is bit-identical for the same seed, and
every generated DAG is acyclic — with hypothesis fuzzing where available."""

import json

import pytest

from repro.core import Workload, synthetic_workload
from repro.core.workload_model import (
    random_layered_workflow,
    stgs_workflows,
    topological_order,
)
from repro.service import arrival_times, chaos_events, continuum_system, generate_trace


# ---------------------------------------------------------------------------
# same seed → bit-identical
# ---------------------------------------------------------------------------

def test_random_layered_workflow_deterministic():
    a = random_layered_workflow(30, seed=7, density=0.5)
    b = random_layered_workflow(30, seed=7, density=0.5)
    assert a == b  # frozen dataclasses: full structural equality
    c = random_layered_workflow(30, seed=8, density=0.5)
    assert a != c


def test_synthetic_workload_deterministic():
    a = synthetic_workload(40, seed=3, num_workflows=3)
    b = synthetic_workload(40, seed=3, num_workflows=3)
    assert a == b
    assert a != synthetic_workload(40, seed=4, num_workflows=3)


def test_stgs_workflows_are_fixed():
    assert stgs_workflows() == stgs_workflows()


def test_arrival_trace_deterministic():
    kw = dict(seed=5, rate=3.0, burst_prob=0.2, burst_size=4)
    assert arrival_times(64, **kw) == arrival_times(64, **kw)
    a = generate_trace(32, seed=5, node_events=True)
    b = generate_trace(32, seed=5, node_events=True)
    assert a.to_json() == b.to_json()
    # and byte-identical through serialization (what a trace file stores)
    assert json.dumps(a.to_json(), sort_keys=True) == json.dumps(
        b.to_json(), sort_keys=True
    )


def test_chaos_trace_deterministic():
    """A chaos-storm trace is a pure function of its seed, end to end."""
    kw = dict(seed=9, chaos={"failure_rate": 0.1, "drift_rate": 0.2,
                             "outage_mean": 10.0})
    a = generate_trace(16, **kw)
    b = generate_trace(16, **kw)
    assert a.to_json() == b.to_json()
    assert a.meta["chaos"]["failure_rate"] == 0.1
    assert any(e.kind == "node-failure" for e in a.events)
    assert generate_trace(32, seed=6).to_json() != a.to_json()


# ---------------------------------------------------------------------------
# every generated DAG is acyclic
# ---------------------------------------------------------------------------

def test_generated_workflows_are_acyclic_over_seeds():
    for seed in range(12):
        wf = random_layered_workflow(25, seed=seed, density=0.7)
        assert topological_order(wf.tasks) is not None
        for w in synthetic_workload(20, seed=seed, num_workflows=2).workflows:
            assert topological_order(w.tasks) is not None


def test_trace_workflows_are_acyclic_and_connected_to_families():
    trace = generate_trace(40, seed=2)
    for sub in trace.submissions:
        assert topological_order(sub.workflow.tasks) is not None
        assert sub.workflow.num_tasks >= 1


# ---------------------------------------------------------------------------
# hypothesis fuzz (optional dependency, mirrored from test_property.py)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - container without hypothesis
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        num_tasks=st.integers(min_value=1, max_value=60),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        density=st.floats(min_value=0.0, max_value=1.0),
        max_width=st.integers(min_value=1, max_value=8),
    )
    def test_random_layered_workflow_always_acyclic(num_tasks, seed, density, max_width):
        wf = random_layered_workflow(
            num_tasks, seed=seed, density=density, max_width=max_width
        )
        assert wf.num_tasks == num_tasks
        assert topological_order(wf.tasks) is not None
        # determinism under the fuzzed parameters too
        assert wf == random_layered_workflow(
            num_tasks, seed=seed, density=density, max_width=max_width
        )

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=50),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        rate=st.floats(min_value=0.1, max_value=20.0),
    )
    def test_arrival_times_monotone_and_deterministic(n, seed, rate):
        a = arrival_times(n, seed=seed, rate=rate)
        assert a == arrival_times(n, seed=seed, rate=rate)
        assert len(a) == n
        assert all(t1 <= t2 for t1, t2 in zip(a, a[1:]))
        assert all(t >= 0.0 for t in a)

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        horizon=st.floats(min_value=1.0, max_value=500.0),
        failure_rate=st.floats(min_value=0.0, max_value=0.5),
        drift_rate=st.floats(min_value=0.0, max_value=0.5),
    )
    def test_chaos_events_deterministic_and_well_formed(
        seed, horizon, failure_rate, drift_rate
    ):
        system = continuum_system()
        kw = dict(seed=seed, failure_rate=failure_rate, drift_rate=drift_rate)
        a = chaos_events(system, horizon, **kw)
        assert a == chaos_events(system, horizon, **kw)
        names = {n.name for n in system.nodes}
        assert all(e.node in names for e in a)
        assert all(x.time <= y.time for x, y in zip(a, a[1:]))
        # drifts carry a positive factor; failures pair with recoveries
        assert all(
            e.factor is not None and e.factor > 0
            for e in a if e.kind == "node-drift"
        )
        kinds = [e.kind for e in a]
        assert kinds.count("node-failure") == kinds.count("node-recovery")
        # only paired recoveries may land past the horizon
        assert all(
            e.time < horizon for e in a if e.kind != "node-recovery"
        )
else:  # pragma: no cover

    def test_hypothesis_unavailable_noted():
        pytest.skip("hypothesis not installed; fuzz variants skipped")
