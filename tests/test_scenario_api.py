"""Scenario-first API: solver registry + plugins, policy routing, Scenario
JSON round-trip, orchestrator closed-loop adaptation, deprecation shims,
and the ``python -m repro`` CLI."""

import json
import subprocess
import sys
import warnings
from pathlib import Path

import numpy as np
import pytest

from repro.core import build_problem, mri_system, mri_workload, synthetic_system, synthetic_workload
from repro.core import api
from repro.core.api import (
    REGISTRY,
    ObjectiveWeights,
    OrchestrationConfig,
    Orchestrator,
    Perturbation,
    Policy,
    PolicyRule,
    Scenario,
    SolveReport,
    SolverRegistry,
    register_solver,
    run_scenario,
    scenario_from_json,
    solve_problem,
    solve_problems,
)
from repro.core.evaluator import evaluate_assignment


def _mri_problem():
    return build_problem(mri_system(), mri_workload())


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_builtins_and_capabilities():
    names = REGISTRY.names()
    for t in ("milp", "milp-static", "heft", "olb", "ga", "pso", "sa", "aco"):
        assert t in names
    assert REGISTRY.capabilities("milp").exact
    assert REGISTRY.capabilities("milp").needs_time_limit
    assert REGISTRY.capabilities("milp").max_tasks == 60
    assert REGISTRY.capabilities("ga").supports_batch
    assert not REGISTRY.capabilities("heft").exact


def test_unknown_technique_message_lists_options():
    with pytest.raises(KeyError, match="unknown technique"):
        REGISTRY.get("quantum")


def test_out_of_tree_plugin_routable_by_technique_and_policy():
    """A solver registered from test code (no core edits) must be routable
    both by ``technique=`` and by a policy rule chain."""

    @register_solver("all-on-n2", exact=False)
    def _all_on_n2(problem, weights=ObjectiveWeights(), **kw) -> SolveReport:
        assignment = np.full(problem.num_tasks, 1, dtype=np.int64)
        sched = evaluate_assignment(problem, assignment, weights, technique="all-on-n2")
        return SolveReport(schedule=sched, problem=problem)

    try:
        prob = _mri_problem()
        # direct technique= routing
        rep = solve_problem(prob, "all-on-n2")
        assert rep.schedule.technique == "all-on-n2"
        assert (rep.schedule.assignment == 1).all()
        # policy routing
        policy = Policy(rules=(PolicyRule("all-on-n2", max_tasks=100),), final="heft")
        rep2 = solve_problem(prob, "policy", policy=policy)
        assert rep2.schedule.technique == "all-on-n2"
        # and through the registry's own route
        rep3 = policy.route(prob)
        assert rep3.schedule.technique == "all-on-n2"
    finally:
        REGISTRY.unregister("all-on-n2")
    assert "all-on-n2" not in REGISTRY


def test_plugin_registry_isolation():
    """A private registry does not leak into the default one."""
    mine = SolverRegistry()

    @register_solver("mine-only", registry=mine)
    def _fn(problem, weights=ObjectiveWeights(), **kw):
        return SolveReport(schedule=None, problem=problem)

    assert "mine-only" in mine
    assert "mine-only" not in REGISTRY
    with pytest.raises(ValueError, match="already registered"):
        mine.register("mine-only", _fn)


def test_policy_size_gates_and_fallback_chain():
    """The paper_hybrid policy reproduces §VII: MILP small, GA mid, HEFT
    large — and capability max_tasks gates MILP out of oversized problems."""
    hybrid = Policy.paper_hybrid()
    small = _mri_problem()
    rep = hybrid.route(small)
    assert rep.schedule.technique.startswith("milp")

    mid = build_problem(synthetic_system(4, seed=0), synthetic_workload(40, seed=0))
    rep = hybrid.route(mid, generations=4, pop_size=16)
    assert rep.schedule.technique == "ga"

    big = build_problem(synthetic_system(8, seed=1), synthetic_workload(700, seed=1))
    rep = hybrid.route(big)
    assert rep.schedule.technique == "heft"


def test_policy_scoped_options_target_one_technique():
    """``milp={"time_limit": ...}`` tunes the MILP rule without leaking an
    unknown kwarg into GA/HEFT, and flat kwargs still reach opted-in rules."""
    hybrid = Policy.paper_hybrid()
    small = _mri_problem()
    rep = hybrid.route(small, milp={"time_limit": 60.0})
    assert rep.schedule.technique.startswith("milp")

    # mid-size: MILP is size-gated out; the scoped milp dict must NOT crash
    # the GA rule, while flat GA knobs still apply
    mid = build_problem(synthetic_system(4, seed=0), synthetic_workload(40, seed=0))
    rep = hybrid.route(mid, milp={"time_limit": 60.0}, generations=4, pop_size=16)
    assert rep.schedule.technique == "ga"


def test_orchestrator_scoped_solver_options():
    s = Scenario(
        name="scoped", system=mri_system(), workload=mri_workload(),
        technique="auto",
        solver_options={"milp": {"time_limit": 10.0}},
    )
    r = run_scenario(s)
    assert r.final_schedule.technique.startswith("milp")
    # direct-technique path drops other techniques' scoped dicts cleanly
    s2 = s.replace(technique="heft")
    r2 = run_scenario(s2)
    assert r2.final_schedule.technique == "heft"


def test_policy_json_roundtrip():
    pol = Policy.paper_hybrid(milp_task_threshold=10, mh_task_threshold=99)
    obj = pol.to_json()
    assert Policy.from_json(obj).to_json() == obj
    assert Policy.from_json(obj) == pol


# ---------------------------------------------------------------------------
# batch routing (ga_sweep fast path reachable from the new API)
# ---------------------------------------------------------------------------

def test_solve_problems_batch_via_registry():
    problems = [
        build_problem(synthetic_system(3, seed=s), synthetic_workload(12, seed=s))
        for s in (0, 1, 2)
    ]
    reports = solve_problems(problems, "ga", generations=4, pop_size=16, seed=0)
    assert len(reports) == 3
    for rep, prob in zip(reports, problems):
        assert rep.problem is prob
        assert rep.schedule.violations == 0
        assert rep.history is not None  # the sweep returns per-instance history


def test_solve_problems_pallas_backend_declines_batch():
    """A per-instance-only kwarg (backend='pallas') must fall back to the
    unbatched path without crashing the sweep."""
    problems = [
        build_problem(synthetic_system(3, seed=s), synthetic_workload(8, seed=s))
        for s in (0, 1)
    ]
    entry = REGISTRY.get("ga")
    assert entry.batch_fn(problems, backend="pallas") is None


# ---------------------------------------------------------------------------
# Scenario JSON round-trip
# ---------------------------------------------------------------------------

def _scenario() -> Scenario:
    return Scenario(
        name="mri-loop",
        system=mri_system(),
        workload=mri_workload(),
        weights=ObjectiveWeights(alpha=2.0, beta=1.0, usage_mode="weighted"),
        technique="policy",
        policy=Policy.paper_hybrid(milp_task_threshold=10),
        backend="simulate",
        perturbation=Perturbation(speed_factors={"N2": 0.5}, jitter=0.0, seed=7),
        orchestration=OrchestrationConfig(max_rounds=4, drift_threshold=0.05,
                                          smoothing=1.0),
        solver_options={"time_limit": 5.0},
    )


def test_scenario_json_roundtrip_bit_exact(tmp_path):
    s = _scenario()
    obj = s.to_json()
    s2 = scenario_from_json(obj)
    assert s2.to_json() == obj  # bit-exact
    # and through a file + load_scenario
    p = s.save(tmp_path / "scenario.json")
    s3 = api.load_scenario(p)
    assert s3.to_json() == obj
    assert s3.name == "mri-loop"
    assert s3.policy == s.policy
    assert s3.perturbation == s.perturbation
    assert s3.weights == s.weights


def test_scenario_file_is_a_valid_snakemake_config(tmp_path):
    """One file specifies the end-to-end run AND still parses through the
    plain Fig. 7/8 config loader."""
    from repro.core.snakemake_io import load_config

    p = _scenario().save(tmp_path / "scenario.json")
    system, workload = load_config(p)
    assert system.num_nodes == 3
    assert workload.num_tasks == 7


def test_scenario_missing_sections_rejected():
    with pytest.raises(ValueError, match="missing"):
        scenario_from_json({"scenario": {"name": "x"}})


def test_scenario_unknown_header_key_did_you_mean():
    """A typo'd header key must fail loudly with a suggestion — it used to
    fall through silently to the default policy."""
    s = Scenario(name="t", system=mri_system(), workload=mri_workload())
    obj = s.to_json()
    obj["scenario"]["tehcnique"] = "heft"
    with pytest.raises(ValueError, match="did you mean 'technique'"):
        scenario_from_json(json.loads(json.dumps(obj)))


def test_scenario_unknown_top_level_section_did_you_mean():
    s = Scenario(name="t", system=mri_system(), workload=mri_workload())
    obj = s.to_json()
    obj["scenari"] = {"name": "x"}  # not a workflow (no 'tasks'), not reserved
    with pytest.raises(ValueError, match="did you mean 'scenario'"):
        scenario_from_json(json.loads(json.dumps(obj)))


def test_scenario_unknown_nested_keys_rejected():
    s = Scenario(name="t", system=mri_system(), workload=mri_workload())
    for section, bad_key, hint in (
        ("weights", "alhpa", "alpha"),
        ("perturbation", "jitterr", "jitter"),
        ("orchestration", "max_round", "max_rounds"),
    ):
        obj = s.to_json()
        obj["scenario"][section][bad_key] = 1.0
        with pytest.raises(ValueError, match=f"did you mean '{hint}'"):
            scenario_from_json(json.loads(json.dumps(obj)))


def test_scenario_reserved_workflow_name_rejected():
    """A workflow named like a scenario-file section would silently clobber
    the header on serialization — reject it loudly instead."""
    from repro.core.workload_model import Task, Workflow, Workload

    wl = Workload((Workflow("scenario", (Task("T1"),)),))
    s = Scenario(name="bad", system=mri_system(), workload=wl)
    with pytest.raises(ValueError, match="reserved"):
        s.to_json()


def test_all_techniques_is_live_view():
    """Plugins registered after import appear in ALL_TECHNIQUES (package,
    api module, and deprecated shim all agree)."""
    import repro.core as core

    @register_solver("late-plugin")
    def _fn(problem, weights=ObjectiveWeights(), **kw):
        return SolveReport(schedule=None, problem=problem)

    try:
        assert "late-plugin" in core.ALL_TECHNIQUES
        assert "late-plugin" in api.ALL_TECHNIQUES
    finally:
        REGISTRY.unregister("late-plugin")
    assert "late-plugin" not in core.ALL_TECHNIQUES


def test_policy_does_not_swallow_approximate_solver_errors():
    """Only exact solvers get the defensive ValueError net; a crash inside
    an approximate technique must propagate, not fall back silently."""

    @register_solver("broken-mh")
    def _broken(problem, weights=ObjectiveWeights(), **kw):
        raise ValueError("real bug")

    try:
        pol = Policy(rules=(PolicyRule("broken-mh"),), final="heft")
        with pytest.raises(ValueError, match="real bug"):
            pol.route(_mri_problem())
    finally:
        REGISTRY.unregister("broken-mh")


# ---------------------------------------------------------------------------
# orchestrator closed loop
# ---------------------------------------------------------------------------

def test_orchestrator_converges_without_perturbation():
    s = Scenario(name="calm", system=mri_system(), workload=mri_workload(),
                 technique="heft")
    r = run_scenario(s)
    assert len(r.reports) == 1
    assert not r.adapted
    assert r.reports[0].slowdown == pytest.approx(1.0)


def test_orchestrator_adapts_to_slow_node():
    """Acceptance: under a ≥2× speed perturbation on one node, the re-solve
    triggered by monitor feedback improves observed makespan vs. the
    unadapted schedule."""
    s = Scenario(
        name="n2-degraded",
        system=mri_system(),
        workload=mri_workload(),
        technique="heft",
        perturbation=Perturbation(speed_factors={"N2": 0.4}),  # 2.5× slower
        orchestration=OrchestrationConfig(max_rounds=3, drift_threshold=0.1,
                                          smoothing=1.0),
    )
    r = run_scenario(s)
    assert len(r.reports) >= 2
    assert r.adapted
    # the monitor learned N2's true speed ...
    assert r.speed_estimates["N2"] == pytest.approx(0.4, rel=1e-6)
    # ... and the re-solved schedule beats the unadapted one where it counts
    assert r.reports[-1].makespan < r.reports[0].makespan
    # converged: the refreshed model predicts observed reality
    assert r.reports[-1].slowdown == pytest.approx(1.0, abs=1e-6)
    assert r.adaptations[0].resolved and not r.adaptations[-1].resolved


def test_orchestrator_render_backend_single_round(tmp_path):
    s = Scenario(name="render", system=mri_system(), workload=mri_workload(),
                 technique="heft", backend="slurm")
    r = Orchestrator(s, out_dir=tmp_path).run()
    assert len(r.schedules) == 1
    assert not r.reports
    assert any(p.name == "submit_all.sh" for p in r.artifacts)
    assert (tmp_path / "submit_all.sh").exists()
    assert "artifacts" in r.summary()


def test_run_result_summary_is_json_serializable():
    s = _scenario()
    r = run_scenario(s)
    text = json.dumps(r.summary())
    obj = json.loads(text)
    assert obj["scenario"] == "mri-loop"
    assert obj["rounds"] == len(r.schedules)


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

def test_solver_shims_delegate_to_api():
    import repro.core.solver as solver

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert solver.solve_problem is api.solve_problem
        assert solver.solve is api.solve
        assert solver.solve_problems is api.solve_problems
        assert solver.compare_techniques is api.compare_techniques
        assert solver.SolveReport is api.SolveReport
        assert set(solver.ALL_TECHNIQUES) >= {"milp", "heft", "ga"}


def test_solver_shim_warns_and_dispatch_is_gone():
    import repro.core.solver as solver

    with pytest.warns(DeprecationWarning, match="repro.core.api"):
        solver.solve_problem
    with pytest.raises(AttributeError):
        solver._DISPATCH


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_run_scenario(tmp_path):
    scen_path = Scenario(
        name="cli-mri", system=mri_system(), workload=mri_workload(),
        technique="olb",
    ).save(tmp_path / "scenario.json")
    out_path = tmp_path / "result.json"
    env_src = str(Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "run", str(scen_path),
         "--technique", "heft", "--out", str(out_path)],
        capture_output=True, text=True,
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr
    summary = json.loads(proc.stdout)
    assert summary["scenario"] == "cli-mri"
    assert summary["technique"] == "heft"  # CLI override wins
    assert summary["rounds"] == 1
    saved = json.loads(out_path.read_text())
    assert saved == summary


def test_cli_lists_techniques(tmp_path):
    env_src = str(Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "techniques"],
        capture_output=True, text=True,
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr
    assert "milp" in proc.stdout and "exact" in proc.stdout
    assert "ga" in proc.stdout and "batch" in proc.stdout
